//! Per-op cost functions: latency (µs) and energy (pJ) of one op on one
//! device model, given operand shapes and the engine it is placed on.
//!
//! Calibration: the functional forms come from the FlexNN-like
//! architecture (paper §IV); the constants live in
//! [`crate::config::HardwareConfig`] and were frozen after matching the
//! paper's Fig. 4/5 breakdown percentages (DESIGN.md §7).

use crate::config::{DeviceKind, HardwareConfig};
use crate::ops::{Engine, OpGraph, OpKind};

/// Cost of one op execution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpCost {
    pub us: f64,
    pub pj: f64,
    pub engine: Engine,
    /// Dense MACs performed (telemetry / roofline accounting).
    pub macs: usize,
}

impl OpCost {
    pub fn zero() -> OpCost {
        OpCost { us: 0.0, pj: 0.0, engine: Engine::Dpu, macs: 0 }
    }
}

/// Vector width of one DPU lane group (f32 elements per vector op) —
/// mirrors the 8-wide register tiles the engine's SIMD microkernels
/// commit per store ([`crate::tensor::matmul_block_simd`]).
pub const DPU_VECTOR_LANES: usize = 8;

/// DPU systolic-array utilization for an (m,k)@(k,n) MatMul: fraction of
/// the MAC grid kept busy. Skinny operands (attention projections, (n,1)
/// vectors) can't fill the array — the paper's "limited parallelism
/// inherent in the GCN" (Fig. 21 discussion) comes from exactly this.
/// The final factor models vector-lane fill: output columns are issued
/// in [`DPU_VECTOR_LANES`]-wide groups, so an `n` that is not a lane
/// multiple pays for the padded remainder lanes.
pub fn matmul_utilization(m: usize, k: usize, n: usize) -> f64 {
    let fill = |d: usize, t: f64| (d as f64 / t).min(1.0);
    let lane_fill = if n == 0 {
        1.0
    } else {
        n as f64 / crate::util::round_up(n, DPU_VECTOR_LANES) as f64
    };
    // 128-wide output stationarity per tile, 64-deep accumulation pipeline
    fill(m, 128.0) * fill(n, 64.0).max(fill(k, 64.0) * fill(n, 8.0)).min(1.0) * lane_fill
}

/// Dense-MAC time on the DPU (or CPU/GPU compute core).
fn matmul_cost(hw: &HardwareConfig, m: usize, k: usize, n: usize,
               dtype_bytes: usize, sparsity_skip: f64) -> OpCost {
    let macs = m * k * n;
    let effective_macs = (macs as f64) * (1.0 - sparsity_skip);
    let util = match hw.kind {
        DeviceKind::Npu => matmul_utilization(m, k, n),
        // CPU microkernels lose efficiency on skinny shapes, but less
        // sharply (no 2-D systolic fill constraint).
        DeviceKind::Cpu => (m.min(64) as f64 / 64.0).max(0.25),
        // integrated GPUs reach ~35% of peak on real GEMMs (driver +
        // occupancy limits on shared-memory SoCs).
        DeviceKind::Gpu => 0.35 * (m.min(64) as f64 / 64.0).max(0.25),
    };
    let peak = hw.macs_per_cycle(dtype_bytes) * hw.clock_ghz * 1e3; // MACs/µs
    let us = effective_macs / (peak * util.max(1e-3));
    let pj_per_mac = hw.pj_per_mac_int8 * dtype_bytes as f64;
    OpCost {
        us,
        pj: effective_macs * pj_per_mac,
        engine: Engine::Dpu,
        macs,
    }
}

/// Vectorizable elementwise/reduction work on the DPU vector units.
fn vector_cost(hw: &HardwareConfig, elems: usize, passes: f64) -> OpCost {
    let lanes = (hw.vector_lanes * hw.tiles) as f64;
    let us = (elems as f64 * passes) / (lanes * hw.clock_ghz * 1e3);
    OpCost {
        us,
        pj: elems as f64 * passes * hw.pj_per_mac_int8 * 2.0,
        engine: Engine::Dpu,
        macs: 0,
    }
}

/// Control-heavy work on the DSP: `serial` irregular steps (one per row /
/// gather / scatter target) plus `elems` of vectorizable payload moved at
/// DSP lane width — both at the DSP's lower clock.
fn dsp_cost(hw: &HardwareConfig, serial: usize, elems: usize) -> OpCost {
    let cycles = serial as f64 * hw.dsp_control_cycles_per_elem
        + elems as f64 / hw.dsp_lanes as f64;
    let us = cycles / (hw.dsp_clock_ghz * 1e3);
    OpCost {
        us,
        pj: (serial + elems) as f64 * hw.pj_per_dsp_elem,
        engine: Engine::Dsp,
        macs: 0,
    }
}

/// Options a simulation threads through to op costing.
#[derive(Debug, Clone, Copy, Default)]
pub struct CostOpts {
    /// GraSp: fraction of MACs skipped in MatMuls whose *stationary*
    /// operand is a sparse structure mask (0 disables).
    pub mask_sparsity_skip: f64,
    /// Operand dtype width override for QuantGr-quantized dense ops.
    pub dense_dtype_bytes: usize,
    /// Density of the `SpMM` sparse operand (0 → [`SPMM_DEFAULT_DENSITY`]).
    /// Unlike `mask_sparsity_skip`, this is *uncapped*: SpMM never
    /// touches the zeros at all (structural sparsity, not a zero-skip
    /// pipeline), so its MAC count is exactly nnz·d.
    pub spmm_density: f64,
}

/// Density assumed for SpMM operands when the caller knows nothing
/// (a conservative citation-graph-scale figure).
pub const SPMM_DEFAULT_DENSITY: f64 = 0.01;

/// MAC-grid efficiency loss of gathered (indexed) rhs rows relative to a
/// streamed dense operand: the SpMM crossover sits at density ≈ 1/this,
/// calibrated to the engine-measured crossover
/// ([`crate::ops::build::SPMM_DENSITY_THRESHOLD`] = 0.25).
pub const SPMM_GATHER_PENALTY: f64 = 4.0;

/// Compute-only cost of `op` on `hw` with the given engine placement.
/// DMA/transfer costs are the scheduler's job ([`super::sim`]).
pub fn op_cost(g: &OpGraph, id: usize, hw: &HardwareConfig,
               engine: Engine, opts: CostOpts) -> OpCost {
    let op = &g.ops[id];
    let in_shape = |k: usize| -> &[usize] { &g.ops[op.inputs[k]].shape };
    let elems = op.num_elements();
    let dtype_bytes = if opts.dense_dtype_bytes > 0 {
        opts.dense_dtype_bytes
    } else {
        2 // NPU default datapath: FP16
    };

    let mut cost = match &op.kind {
        OpKind::Input => OpCost::zero(),

        OpKind::MatMul => {
            let a = in_shape(0);
            let b = in_shape(1);
            // GraSp zero-skip applies when the lhs is a structure mask
            // (the n×n aggregation); detect via "mask-like" input names.
            let lhs = &g.ops[op.inputs[0]];
            let skip = if lhs.kind == OpKind::Input && is_mask_name(&lhs.name) {
                opts.mask_sparsity_skip
            } else {
                0.0
            };
            matmul_cost(hw, a[0], a[1], b[1], dtype_bytes, skip)
        }
        OpKind::SpMM => {
            // GraSp made structural: the sparse aggregation performs
            // exactly nnz·d MACs (density · m·k·n) — an *uncapped* skip,
            // unlike the 75%-capped zero-skip pipeline — but gathered rhs
            // rows keep only ~1/PENALTY of the MAC grid busy, plus a
            // per-entry address walk on the vector lanes. The resulting
            // crossover vs the dense MatMul lands at density ≈
            // 1/SPMM_GATHER_PENALTY, matching the engine-measured
            // [`crate::ops::build::SPMM_DENSITY_THRESHOLD`], which is what
            // makes plan-vs-dense decisions principled rather than ad hoc.
            let a = in_shape(0);
            let b = in_shape(1);
            let density = if opts.spmm_density > 0.0 {
                opts.spmm_density
            } else {
                SPMM_DEFAULT_DENSITY
            }
            .min(1.0);
            let mut c = matmul_cost(hw, a[0], a[1], b[1], dtype_bytes, 1.0 - density);
            c.us *= SPMM_GATHER_PENALTY;
            let nnz = (a[0] * a[1]) as f64 * density;
            let lanes = (hw.vector_lanes * hw.tiles) as f64;
            c.us += nnz / (lanes * hw.clock_ghz * 1e3);
            c.pj += nnz * hw.pj_per_dsp_elem;
            c
        }
        OpKind::QMatMul { .. } => {
            let a = in_shape(0);
            let b = in_shape(1);
            matmul_cost(hw, a[0], a[1], b[1], 1, 0.0) // INT8 datapath
        }
        OpKind::MaskedMaxPool => {
            // GrAx3 maps mask-multiply + max-pool onto the MAC grid
            // (a (×, max)-semiring MatMul — paper Fig. 18); zero mask
            // entries are skippable exactly like GraSp MatMul zeros.
            let m = in_shape(0)[0];
            let n = in_shape(0)[1];
            let f = in_shape(1)[1];
            let lhs = &g.ops[op.inputs[0]];
            let skip = if lhs.kind == OpKind::Input && is_mask_name(&lhs.name) {
                opts.mask_sparsity_skip
            } else {
                0.0
            };
            matmul_cost(hw, m, n, f, dtype_bytes, skip)
        }
        OpKind::Transpose => vector_cost(hw, elems, 1.5), // strided copy
        OpKind::Add | OpKind::Sub | OpKind::Mul => vector_cost(hw, elems, 1.0),
        OpKind::Scale(_) | OpKind::AddConst(_) | OpKind::Relu
        | OpKind::LeakyRelu(_) => vector_cost(hw, elems, 1.0),
        OpKind::Exp => vector_cost(hw, elems, 2.0), // polynomial approx
        OpKind::BroadcastCol | OpKind::BroadcastRow => vector_cost(hw, elems, 1.0),
        OpKind::ReduceSumRows | OpKind::ReduceMaxRows => {
            vector_cost(hw, in_shape(0).iter().product(), 1.0)
        }
        OpKind::Quantize { .. } => vector_cost(hw, elems, 1.0),

        // ---- DSP-class ----
        // Vectorizable-but-DSP-bound ops pay per-row serialization plus
        // payload at DSP lane width (they vectorize along the row).
        OpKind::Div => {
            let payload: usize = in_shape(0).iter().product();
            dsp_cost(hw, in_shape(0)[0], payload)
        }
        OpKind::Sqrt | OpKind::Rsqrt | OpKind::Reciprocal => {
            dsp_cost(hw, elems, elems)
        }
        OpKind::Elu => dsp_cost(hw, in_shape(0)[0], elems),
        OpKind::Greater | OpKind::Select => {
            let payload: usize = in_shape(0).iter().product();
            dsp_cost(hw, in_shape(0)[0], payload)
        }
        OpKind::Softmax => {
            // two payload passes (fused max/exp/sum, then normalize)
            // with per-row serialization on the reduce phase
            let payload: usize = in_shape(0).iter().product();
            dsp_cost(hw, in_shape(0)[0], payload * 2)
        }
        OpKind::DegreesFromEdges => {
            let m = in_shape(0)[0];
            dsp_cost(hw, 2 * m, 2 * m)
        }
        OpKind::AdjacencyFromEdges => {
            let m = in_shape(0)[0];
            // materializing a dense mask from edge tuples is serial DSP
            // work per element (init + layout) plus 2m scattered writes —
            // the dominant preprocessing cost of Fig. 4
            dsp_cost(hw, elems / 4 + 2 * m, elems)
        }
        OpKind::ScatterAddEdges => {
            let m = in_shape(0)[0];
            let f = in_shape(1)[1];
            dsp_cost(hw, 2 * m, 2 * m * f)
        }
        OpKind::NeighborGatherMax | OpKind::NeighborGatherMean => {
            let n = in_shape(0)[0];
            let k = in_shape(0)[1];
            let f = in_shape(1)[1];
            dsp_cost(hw, n * k, n * k * f)
        }
    };

    // Engine override: when GraphSplit sends a DSP-class op to the CPU
    // model, or the caller forces DPU execution of a rewritten op, the
    // placement decides, not the op's default.
    cost.engine = engine;
    if hw.kind != DeviceKind::Npu {
        // CPU/GPU have no DPU/DSP split: tag by the op's default class
        // for reporting, but the cost above already used hw's constants.
        cost.engine = op.kind.default_engine();
    }
    // fixed per-op scheduling overhead
    cost.us += hw.op_overhead_us;
    // static power charged over the op's latency: W · µs = 1e6 pJ
    cost.pj += hw.static_watts * cost.us * 1e6;
    cost
}

/// Structure-mask input names (GraSp's sparsity targets).
pub fn is_mask_name(name: &str) -> bool {
    matches!(name, "norm" | "adj" | "mask" | "norm_mask" | "neg_bias" | "norm_pad")
}

/// Per-op-kind multiplicative latency corrections, fitted from observed
/// executions by the telemetry calibration loop
/// ([`crate::telemetry::profile::CalibrationReport::scales`]). Kinds
/// without an observation pass through at 1.0, so an empty `CostScales`
/// makes [`op_cost_scaled`] identical to [`op_cost`] — the model stays
/// usable before any telemetry exists.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CostScales {
    factors: std::collections::BTreeMap<String, f64>,
}

impl CostScales {
    /// Set the correction for one op-kind mnemonic
    /// ([`OpKind::name`]). Non-finite or non-positive factors are
    /// ignored (a degenerate fit must not zero the cost model).
    pub fn set(&mut self, kind: &str, factor: f64) {
        if factor.is_finite() && factor > 0.0 {
            self.factors.insert(kind.to_string(), factor);
        }
    }

    /// The correction for `kind` (1.0 when unfitted).
    pub fn factor(&self, kind: &str) -> f64 {
        *self.factors.get(kind).unwrap_or(&1.0)
    }

    /// True when no kind has a fitted correction.
    pub fn is_empty(&self) -> bool {
        self.factors.is_empty()
    }

    /// Fitted (kind, factor) pairs in kind order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, f64)> {
        self.factors.iter().map(|(k, &v)| (k.as_str(), v))
    }
}

/// [`op_cost`] with the fitted per-kind latency correction applied (the
/// observed/predicted energy split is not calibrated — only `us` moves).
pub fn op_cost_scaled(g: &OpGraph, id: usize, hw: &HardwareConfig,
                      engine: Engine, opts: CostOpts,
                      scales: &CostScales) -> OpCost {
    let mut c = op_cost(g, id, hw, engine, opts);
    c.us *= scales.factor(g.ops[id].kind.name());
    c
}

/// Calibrated compute µs of one full execution of `g` on `hw`: every op
/// priced at its default engine placement through [`op_cost_scaled`].
/// This is the whole-graph score the spec autotuner ranks candidate
/// deployments with; an empty [`CostScales`] makes it the raw model.
pub fn graph_cost_scaled(g: &OpGraph, hw: &HardwareConfig, opts: CostOpts,
                         scales: &CostScales) -> f64 {
    (0..g.ops.len())
        .map(|id| {
            op_cost_scaled(g, id, hw, g.ops[id].kind.default_engine(), opts, scales).us
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::build::{gcn_stagr, GnnDims};
    use crate::ops::Stage;
    use crate::tensor::DType;

    fn hw() -> HardwareConfig {
        HardwareConfig::npu_series2()
    }

    fn graph_with(kind: OpKind, a: &[usize], b: Option<&[usize]>, out: &[usize]) -> OpGraph {
        let mut g = OpGraph::new("t");
        let x = g.input("x", a, DType::F32, Stage::Compute);
        let inputs = match b {
            Some(bs) => {
                let y = g.input("y", bs, DType::F32, Stage::Compute);
                vec![x, y]
            }
            None => vec![x],
        };
        let o = g.op(kind, &inputs, out, Stage::Compute);
        g.set_output(o);
        g
    }

    #[test]
    fn big_matmul_near_peak() {
        let g = graph_with(OpKind::MatMul, &[2048, 1433], Some(&[1433, 64]), &[2048, 64]);
        let c = op_cost(&g, 2, &hw(), Engine::Dpu, CostOpts::default());
        let macs = 2048 * 1433 * 64;
        let peak_us = macs as f64 / (hw().macs_per_cycle(2) * hw().clock_ghz * 1e3);
        assert!(c.us < peak_us * 3.0, "{} vs peak {}", c.us, peak_us);
        assert_eq!(c.macs, macs);
    }

    #[test]
    fn skinny_matmul_underutilizes() {
        // (n,64)@(64,1): the GAT projection that can't fill the array
        let g = graph_with(OpKind::MatMul, &[2048, 64], Some(&[64, 1]), &[2048, 1]);
        let c = op_cost(&g, 2, &hw(), Engine::Dpu, CostOpts::default());
        let peak_us = (2048.0 * 64.0) / (hw().macs_per_cycle(2) * hw().clock_ghz * 1e3);
        assert!(c.us > peak_us * 3.0, "skinny should be inefficient");
    }

    #[test]
    fn dsp_slower_than_dpu_for_same_elems() {
        let n = 1_000_000;
        let g_sel = graph_with(OpKind::Select, &[1000, 1000], Some(&[1000, 1000]), &[1000, 1000]);
        // select needs 3 inputs; build manually
        let mut g = OpGraph::new("sel");
        let c0 = g.input("c", &[1000, 1000], DType::F32, Stage::Compute);
        let a = g.input("a", &[1000, 1000], DType::F32, Stage::Compute);
        let b = g.input("b", &[1000, 1000], DType::F32, Stage::Compute);
        let s = g.op(OpKind::Select, &[c0, a, b], &[1000, 1000], Stage::Compute);
        g.set_output(s);
        let dsp = op_cost(&g, 3, &hw(), Engine::Dsp, CostOpts::default());

        let g2 = graph_with(OpKind::Mul, &[1000, 1000], Some(&[1000, 1000]), &[1000, 1000]);
        let dpu = op_cost(&g2, 2, &hw(), Engine::Dpu, CostOpts::default());
        assert!(
            dsp.us > 5.0 * dpu.us,
            "DSP {} should be ≫ DPU {} for {n} elems",
            dsp.us,
            dpu.us
        );
        let _ = g_sel;
    }

    #[test]
    fn int8_matmul_faster_than_fp16() {
        let g = graph_with(OpKind::MatMul, &[2048, 1024], Some(&[1024, 64]), &[2048, 64]);
        let fp16 = op_cost(&g, 2, &hw(), Engine::Dpu, CostOpts::default());
        let mut gq = OpGraph::new("q");
        let x = gq.input("x", &[2048, 1024], DType::I8, Stage::Compute);
        let w = gq.input("w", &[1024, 64], DType::I8, Stage::Compute);
        let o = gq.op(
            OpKind::QMatMul { x_scale: 1.0, w_scale: 1.0 },
            &[x, w],
            &[2048, 64],
            Stage::Compute,
        );
        gq.set_output(o);
        let int8 = op_cost(&gq, 2, &hw(), Engine::Dpu, CostOpts::default());
        assert!(
            int8.us < fp16.us * 0.7,
            "INT8 {} should beat FP16 {}",
            int8.us,
            fp16.us
        );
    }

    #[test]
    fn grasp_skip_reduces_masked_matmul_cost() {
        let d = GnnDims::model(2048, 4000, 256, 8);
        let g = gcn_stagr(d, "stagr");
        // find the aggregation matmul (norm @ mm)
        let agg_id = g
            .ops
            .iter()
            .enumerate()
            .find(|(_, op)| {
                op.kind == OpKind::MatMul
                    && g.ops[op.inputs[0]].name == "norm"
            })
            .map(|(i, _)| i)
            .unwrap();
        let dense = op_cost(&g, agg_id, &hw(), Engine::Dpu, CostOpts::default());
        let sparse = op_cost(
            &g,
            agg_id,
            &hw(),
            Engine::Dpu,
            CostOpts { mask_sparsity_skip: 0.99, ..Default::default() },
        );
        assert!(sparse.us < dense.us * 0.35, "{} vs {}", sparse.us, dense.us);
    }

    #[test]
    fn spmm_crossover_tracks_the_engine_threshold() {
        // (4096,4096)@(4096,64): a citation-graph-scale aggregation shape,
        // big enough that per-op overhead does not mask the MAC terms
        let dense_g = graph_with(OpKind::MatMul, &[4096, 4096], Some(&[4096, 64]), &[4096, 64]);
        let dense = op_cost(&dense_g, 2, &hw(), Engine::Dpu, CostOpts::default());
        let spmm_at = |density: f64| {
            let mut g = OpGraph::new("s");
            let a = g.input("norm", &[4096, 4096], DType::F32, Stage::Compute);
            let b = g.input("h", &[4096, 64], DType::F32, Stage::Compute);
            let o = g.op(OpKind::SpMM, &[a, b], &[4096, 64], Stage::Compute);
            g.set_output(o);
            op_cost(
                &g,
                2,
                &hw(),
                Engine::Dpu,
                CostOpts { spmm_density: density, ..Default::default() },
            )
        };
        // Cora density: sparse aggregation is an order of magnitude cheaper
        let cora = spmm_at(0.002);
        assert!(cora.us < dense.us * 0.1, "{} !< {}", cora.us, dense.us * 0.1);
        // fully dense operand: the gather penalty makes SpMM the wrong call
        let full = spmm_at(1.0);
        assert!(full.us > dense.us, "{} !> {}", full.us, dense.us);
        // the crossover sits near the engine-measured threshold
        let at_threshold = spmm_at(crate::ops::build::SPMM_DENSITY_THRESHOLD);
        let ratio = at_threshold.us / dense.us;
        assert!(
            (0.5..2.0).contains(&ratio),
            "crossover ratio {ratio:.2} strayed from the engine threshold"
        );
        // monotone in density
        assert!(spmm_at(0.01).us < spmm_at(0.1).us);
        assert!(spmm_at(0.1).us < spmm_at(0.5).us);
    }

    #[test]
    fn every_op_kind_has_finite_cost() {
        // exercise via a full model graph
        let d = GnnDims::model(64, 100, 32, 4);
        for (m, v) in [
            ("gcn", "baseline"),
            ("gat", "baseline"),
            ("gat", "grax"),
            ("sage_max", "baseline"),
            ("sage_max", "grax3"),
        ] {
            let g = crate::ops::build::build(m, v, d).unwrap();
            for id in 0..g.len() {
                let c = op_cost(&g, id, &hw(), g.ops[id].kind.default_engine(),
                                CostOpts::default());
                assert!(c.us.is_finite() && c.us >= 0.0, "{m}/{v} op {id}");
                assert!(c.pj.is_finite() && c.pj >= 0.0);
            }
        }
    }

    #[test]
    fn cost_scales_correct_only_fitted_kinds() {
        let g = graph_with(OpKind::MatMul, &[256, 64], Some(&[64, 32]), &[256, 32]);
        let base = op_cost(&g, 2, &hw(), Engine::Dpu, CostOpts::default());

        let mut scales = CostScales::default();
        assert!(scales.is_empty());
        scales.set("MatMul", 2.5);
        scales.set("Softmax", 0.5);
        scales.set("Relu", f64::NAN); // ignored
        scales.set("Add", 0.0); // ignored

        let scaled = op_cost_scaled(&g, 2, &hw(), Engine::Dpu,
                                    CostOpts::default(), &scales);
        assert!((scaled.us - base.us * 2.5).abs() < 1e-9);
        assert_eq!(scaled.macs, base.macs, "only latency is corrected");
        assert_eq!(scales.factor("Relu"), 1.0, "degenerate fits ignored");
        assert_eq!(scales.factor("Add"), 1.0);
        assert_eq!(scales.factor("Transpose"), 1.0, "unfitted passes through");
        assert_eq!(scales.iter().count(), 2);

        // empty scales: identical to the unscaled model
        let noop = op_cost_scaled(&g, 2, &hw(), Engine::Dpu,
                                  CostOpts::default(), &CostScales::default());
        assert_eq!(noop.us, base.us);
    }

    #[test]
    fn utilization_reflects_vector_lane_fill() {
        // lane-multiple widths fill the vector units completely...
        let aligned = matmul_utilization(2048, 1024, 64);
        // ...an off-by-one width pays for the padded remainder lanes
        let ragged = matmul_utilization(2048, 1024, 65);
        let expected = 65.0 / 72.0; // 65 columns issued as 9 groups of 8
        let ratio = ragged / aligned;
        assert!(
            (ratio - expected).abs() < 1e-9,
            "lane fill ratio {ratio} != {expected}"
        );
        // degenerate width keeps utilization finite and positive
        assert!(matmul_utilization(16, 16, 0) >= 0.0);
    }

    #[test]
    fn mask_names_detected() {
        assert!(is_mask_name("norm"));
        assert!(is_mask_name("neg_bias"));
        assert!(!is_mask_name("x"));
        assert!(!is_mask_name("w1"));
    }
}
