//! # GraNNite — high-performance GNN execution on resource-constrained NPUs
//!
//! Rust + JAX + Pallas reproduction of *GraNNite: Enabling High-Performance
//! Execution of Graph Neural Networks on Resource-Constrained Neural
//! Processing Units* (Das et al., 2025).
//!
//! This crate is Layer 3 of the three-layer stack: the request-path
//! coordinator. Python/JAX (Layers 1–2) runs only at build time
//! (`make artifacts`) to lower the GNN models — with their Pallas kernels —
//! to HLO text; this crate loads those artifacts through the PJRT C API
//! ([`runtime`]), drives them with graphs prepared by the CPU-side
//! techniques ([`graph`]: PreG, SymG, NodePad, GrAd, GraSp), schedules them
//! with the paper's coordination contribution ([`coordinator`]: GraphSplit
//! cost-model partitioning, CacheG state, batching), and evaluates the
//! hardware questions on an NPU simulator ([`npu`]) with Intel Core Ultra
//! Series 1/2-like configurations.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`util`] | PRNG, property-testing harness, tables, timing |
//! | [`config`] | TOML-subset parser + typed hardware/run configs |
//! | [`graph`] | graph substrate: CSR, PreG/SymG/NodePad/GrAd/GraSp, datasets |
//! | [`ops`] | OpenVINO-like op IR, GNN graph builders, EffOp/GrAx rewrites, reference executor |
//! | [`npu`] | NPU simulator: DPU/DSP/SRAM/DMA/energy; CPU & GPU device models |
//! | [`quant`] | QuantGr: symmetric static INT8 |
//! | [`coordinator`] | GraphSplit partitioner, planner, executor, batcher, CacheG |
//! | [`runtime`] | PJRT client, artifact registry, `.gnnt` IO |
//! | [`server`] | dynamic-graph serving: router, workers, GrAd updates |
//! | [`metrics`] | latency/energy/throughput accounting |
//! | [`bench`] | the in-tree benchmark harness + paper-figure drivers |

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod graph;
pub mod metrics;
pub mod npu;
pub mod ops;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Paper-matched model dimensions: hidden width used by every 2-layer GNN.
pub const HIDDEN: usize = 64;

/// GraphSAGE neighbor-sample cap (paper §V: "maximum of 10 randomly
/// selected neighbor nodes").
pub const SAGE_MAX_NEIGHBORS: usize = 10;
