//! # GraNNite — high-performance GNN execution on resource-constrained NPUs
//!
//! Rust + JAX + Pallas reproduction of *GraNNite: Enabling High-Performance
//! Execution of Graph Neural Networks on Resource-Constrained Neural
//! Processing Units* (Das et al., 2025).
//!
//! This crate is Layer 3 of the three-layer stack: the request-path
//! coordinator. Python/JAX (Layers 1–2) runs only at build time
//! (`make artifacts`) to train the models and emit the artifact manifest
//! + weights; this crate rebuilds each artifact's op graph, compiles it
//! once into an [`ops::plan::ExecPlan`], and serves it through the
//! planned executor ([`engine`]) — buffer-arena reuse, fused elementwise
//! chains, a real INT8 path, and row-sharded matmuls. Requests are driven
//! with graphs prepared by the CPU-side techniques ([`graph`]: PreG,
//! SymG, NodePad, GrAd, GraSp), scheduled by the paper's coordination
//! contribution ([`coordinator`]: GraphSplit cost-model partitioning,
//! CacheG state, batching), and evaluated against the hardware questions
//! on an NPU simulator ([`npu`]) with Intel Core Ultra Series 1/2-like
//! configurations.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`util`] | PRNG, property-testing harness, cache-line-aligned slabs ([`util::aligned`]), tables, timing |
//! | [`config`] | TOML-subset parser + typed hardware/run configs |
//! | [`tensor`] | dense [`tensor::Mat`], sparse [`tensor::CsrMat`] (the SpMM operand), dtype-tagged [`tensor::Tensor`] |
//! | [`graph`] | graph substrate: CSR, PreG/SymG/NodePad/GrAd/GraSp, datasets |
//! | [`ops`] | OpenVINO-like op IR, GNN graph builders (sparse or dense aggregation via [`ops::build::Aggregation`]), EffOp/GrAx rewrites, reference executor, [`ops::plan`] compile-once plans with kernel dispatch knobs ([`ops::plan::KernelConfig`]) and CacheG node reordering ([`ops::plan::Reordering`]) |
//! | [`engine`] | planned executor: aligned buffer arena, fused chains, SIMD microkernels (bit-comparable with the scalar oracle), nnz-balanced degree-binned SpMM dispatch, worker pool, gather/scatter tile runner |
//! | [`incremental`] | delta-driven inference: dirty-frontier recompute over a layer-activation cache |
//! | [`npu`] | NPU simulator: DPU/DSP/SRAM/DMA/energy; CPU & GPU device models |
//! | [`quant`] | QuantGr: symmetric static INT8 |
//! | [`coordinator`] | GraphSplit partitioner, planner, executor, batcher, CacheG |
//! | [`runtime`] | PJRT client, artifact registry, `.gnnt` IO |
//! | [`storage`] | out-of-core features: paged `.gnnt`-compatible store, TinyLFU-admission page cache with epoch invalidation, frontier-driven prefetch, all behind [`storage::FeatureSource`] |
//! | [`serve`] | **the serving front door**: [`serve::DeploymentSpec`] + [`serve::Deployment`] + the object-safe [`serve::Serving`] trait + the engine registry |
//! | [`server`] | the single-leader worker loop (the 1-shard [`serve::Serving`] topology) |
//! | [`fleet`] | sharded multi-device serving: placement, halo exchange, routing, admission (the N-shard topology) |
//! | [`metrics`] | latency/energy/throughput/halo accounting (per-shard sinks, bounded reservoirs) |
//! | [`telemetry`] | query tracing (per-worker span rings), per-op plan profiling, cost-model calibration, Prometheus/JSON exporters — off by default, zero hot-path cost when disabled |
//! | [`monitor`] | operational surface: history rings, SLO burn-rate monitor, stall watchdog, flight recorder, `std::net` scrape endpoint (`/metrics`, `/health`, `/traces`, `/events`) — off by default, branch-only when disabled |
//! | [`bench`] | the in-tree benchmark harness + paper-figure drivers |
//!
//! ## Serving (the `serve` front door)
//!
//! Every serving topology launches from one typed value:
//!
//! ```text
//! DeploymentSpec { model, engine, topology, aggregation, quant, batch, admission }
//!        │  (TOML-round-trippable; validated with actionable errors)
//!        ▼
//! Deployment::launch(&spec, &data) ──▶ Box<dyn Serving>
//!        │                                 query / query_wait / query_deadline
//!        │                                 update / sync / metrics / shutdown
//!        ├─ shards = 1 → ServerHandle (single leader — same trait)
//!        └─ shards > 1 → Fleet (placement + halo + routing)
//! ```
//!
//! Engines are looked up by name in a [`serve::EngineRegistry`]
//! (built-ins: `local`, `plan`, `incremental`, `coordinator`); adding an
//! engine is one [`serve::EngineFactory`] impl + one `register` call.
//!
//! ## Scaling model (the `fleet` layer)
//!
//! One logical graph is served by `N` shard workers, each pinned to a
//! simulated device (Series-1/2 NPU, CPU, iGPU). Per inference round,
//! shard `s` costs
//!
//! ```text
//! round(s) = owned(s) · rate(device_s)                    — compute
//!          + setup + halo_in(s) · F · dtype / bandwidth   — halo exchange
//! ```
//!
//! and the fleet's round latency is `max_s round(s)`. `rate` comes from
//! the paper's op-level cost functions ([`npu::cost`]) probed on the real
//! model graph; the halo term charges boundary-node features over the
//! same host link GraphSplit boundary crossings pay. Adding shards
//! shrinks `owned(s)` linearly while growing the cut — the placement
//! planner ([`fleet::placement`]) stops cutting where the link cost
//! overtakes the compute win, which is GraphSplit's §IV tradeoff lifted
//! from ops to nodes. The single-leader [`server`] is the 1-shard
//! special case (no halo, unbounded admission).
//!
//! ## Incremental serving (the `incremental` layer)
//!
//! Churn-dominated workloads mutate a few edges per query; a k-layer
//! GNN output can only change inside the k-hop ball of a mutation, so
//! the delta-driven engine recomputes `O(|frontier|)` rows per round
//! instead of `O(|V|)`, serving everything else from an epoch-versioned
//! layer-activation cache (CacheG generalized from masks to
//! activations). The frontier grows with churn — per round the engine
//! compares the bucketed-tile cost of the frontier pass against the
//! full pass and **falls back to full recompute past the crossover**,
//! so small-churn wins never become large-churn regressions. In a
//! fleet, each shard maintains layer `l` for `B(owned, k−1−l)` and
//! recosts its halo imports from the live frontier rings.
//!
//! ## Sparse aggregation (the SpMM path)
//!
//! Aggregation masks are ~99.8% zero at citation-graph scale, so every
//! engine lowers the `norm @ h` step to a CSR
//! [`ops::OpKind::SpMM`] by default ([`ops::build::Aggregation::Auto`]):
//! O(nnz·d) MACs instead of O(n²·d), CSR DMA instead of a dense n×n
//! mask, and no capacity² buffer anywhere in the plan, tile, or shard.
//! Dense aggregation survives behind the density crossover
//! ([`ops::build::SPMM_DENSITY_THRESHOLD`]) and as the property-test
//! oracle; `npu::cost` prices SpMM with the GraSp model so the
//! simulator and the CPU kernels agree on where the crossover sits.

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod fleet;
pub mod graph;
pub mod incremental;
pub mod metrics;
pub mod monitor;
pub mod npu;
pub mod ops;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod server;
pub mod storage;
pub mod telemetry;
pub mod tensor;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Paper-matched model dimensions: hidden width used by every 2-layer GNN.
pub const HIDDEN: usize = 64;

/// GraphSAGE neighbor-sample cap (paper §V: "maximum of 10 randomly
/// selected neighbor nodes").
pub const SAGE_MAX_NEIGHBORS: usize = 10;
