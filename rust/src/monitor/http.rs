//! The scrape endpoint: a dependency-free `std::net` HTTP/1.1 server.
//!
//! Bound only when the spec's `[monitor] addr` is set
//! ([`crate::monitor::Monitor::bind`]); one accept thread serves one
//! request per connection (`Connection: close`), which is exactly the
//! shape Prometheus scrapes and `curl` checks take. Routes:
//!
//! | route      | body                                                  |
//! |------------|-------------------------------------------------------|
//! | `/metrics` | Prometheus text over live shard snapshots             |
//! | `/health`  | liveness + SLO JSON — `200` healthy, `503` otherwise  |
//! | `/traces`  | stitched query traces, JSON lines                     |
//! | `/events`  | flight-recorder breadcrumbs, JSON lines               |
//!
//! Anything else is a `404` that lists the routes. The serving thread
//! holds a [`Monitor`] clone and renders through its public accessors,
//! so the endpoint can never observe half-updated state.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::thread::JoinHandle;
use std::time::Duration;

use super::Monitor;

/// How long a connected client gets to send its request line.
const READ_TIMEOUT: Duration = Duration::from_secs(2);

/// Spawn the accept loop. [`Monitor::stop`] unblocks it with a
/// throwaway connection after setting the stop flag.
pub(crate) fn spawn(monitor: Monitor, listener: TcpListener) -> JoinHandle<()> {
    std::thread::spawn(move || {
        for stream in listener.incoming() {
            if monitor.stopping() {
                break;
            }
            if let Ok(mut stream) = stream {
                let _ = handle(&monitor, &mut stream);
            }
        }
    })
}

/// Serve one request on one connection, best effort.
fn handle(monitor: &Monitor, stream: &mut TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(READ_TIMEOUT))?;
    let (method, path) = read_request_line(stream)?;
    let (status, content_type, body) = route(monitor, &method, &path);
    stream.write_all(response(status, content_type, &body).as_bytes())?;
    stream.flush()
}

/// Read up to the end of the request line and split out method + path
/// (query strings are ignored). Headers and body, if any, are left
/// unread — we answer and close.
fn read_request_line(stream: &mut TcpStream) -> std::io::Result<(String, String)> {
    let mut line: Vec<u8> = Vec::with_capacity(128);
    let mut byte = [0u8; 1];
    while line.len() < 4096 {
        let n = stream.read(&mut byte)?;
        if n == 0 || byte[0] == b'\n' {
            break;
        }
        if byte[0] != b'\r' {
            line.push(byte[0]);
        }
    }
    let line = String::from_utf8_lossy(&line);
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts
        .next()
        .unwrap_or("/")
        .split('?')
        .next()
        .unwrap_or("/")
        .to_string();
    Ok((method, path))
}

/// Map a request to `(status, content type, body)`.
fn route(monitor: &Monitor, method: &str, path: &str) -> (u16, &'static str, String) {
    if method != "GET" {
        return (405, "text/plain; charset=utf-8",
                "only GET is supported\n".to_string());
    }
    match path {
        "/metrics" => (
            200,
            // the content type Prometheus' text exposition format uses
            "text/plain; version=0.0.4; charset=utf-8",
            monitor.render_prometheus(),
        ),
        "/health" => {
            let Some(report) = monitor.health() else {
                return (503, "application/json",
                        "{\"healthy\":false,\"error\":\"monitor disabled\"}\n"
                            .to_string());
            };
            let status = if report.healthy { 200 } else { 503 };
            let mut body = report.to_json();
            body.push('\n');
            (status, "application/json", body)
        }
        "/traces" => (200, "application/json", monitor.render_traces()),
        "/events" => (200, "application/json", monitor.render_events()),
        _ => (
            404,
            "text/plain; charset=utf-8",
            "not found — try /metrics, /health, /traces, /events\n".to_string(),
        ),
    }
}

/// Render a full HTTP/1.1 response.
fn response(status: u16, content_type: &str, body: &str) -> String {
    let reason = match status {
        200 => "OK",
        404 => "Not Found",
        405 => "Method Not Allowed",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    };
    format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Metrics;
    use crate::monitor::MonitorConfig;
    use std::sync::Arc;

    /// Raw client: connect, send a request line, read to EOF.
    fn get(addr: std::net::SocketAddr, request: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(request.as_bytes()).unwrap();
        let mut out = String::new();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.read_to_string(&mut out).unwrap();
        out
    }

    fn live_monitor() -> (Monitor, std::net::SocketAddr) {
        let m = Monitor::new(MonitorConfig {
            interval: Duration::from_millis(50),
            ..MonitorConfig::default()
        });
        let sink = Arc::new(Metrics::new_shard(0));
        let pulse = m.register_shard(0, sink.clone());
        sink.record_query(120.0, 2.0, 1);
        pulse.touch();
        let addr = m.bind("127.0.0.1:0").unwrap();
        m.start();
        (m, addr)
    }

    #[test]
    fn metrics_health_and_404_over_a_real_socket() {
        let (m, addr) = live_monitor();
        let metrics = get(addr, "GET /metrics HTTP/1.1\r\n\r\n");
        assert!(metrics.starts_with("HTTP/1.1 200 OK"), "{metrics}");
        assert!(metrics.contains("text/plain; version=0.0.4"), "{metrics}");
        let body = metrics.split("\r\n\r\n").nth(1).unwrap();
        crate::telemetry::export::validate_prometheus(body).unwrap();

        let health = get(addr, "GET /health HTTP/1.1\r\n\r\n");
        assert!(health.starts_with("HTTP/1.1 200 OK"), "{health}");
        assert!(health.contains("\"healthy\":true"), "{health}");

        let miss = get(addr, "GET /nope HTTP/1.1\r\n\r\n");
        assert!(miss.starts_with("HTTP/1.1 404"), "{miss}");
        assert!(miss.contains("/metrics"), "404 lists the routes: {miss}");

        let post = get(addr, "POST /metrics HTTP/1.1\r\n\r\n");
        assert!(post.starts_with("HTTP/1.1 405"), "{post}");
        m.stop();
    }

    #[test]
    fn health_goes_503_when_a_shard_wedges() {
        let (m, addr) = live_monitor();
        // the registered pulse stops beating; one 50 ms interval later
        // the endpoint must report the wedge
        std::thread::sleep(Duration::from_millis(120));
        let health = get(addr, "GET /health HTTP/1.1\r\n\r\n");
        assert!(health.starts_with("HTTP/1.1 503"), "{health}");
        assert!(health.contains("\"wedged\":true"), "{health}");
        m.stop();
    }

    #[test]
    fn traces_and_events_serve_json_lines() {
        let (m, addr) = live_monitor();
        m.sample_now();
        let traces = get(addr, "GET /traces HTTP/1.1\r\n\r\n");
        assert!(traces.starts_with("HTTP/1.1 200 OK"), "{traces}");
        let body = traces.split("\r\n\r\n").nth(1).unwrap();
        crate::telemetry::export::validate_json_lines(body).unwrap();
        let events = get(addr, "GET /events HTTP/1.1\r\n\r\n");
        assert!(events.contains("\"kind\":\"launch\""), "{events}");
        m.stop();
    }

    #[test]
    fn stop_unblocks_the_accept_loop() {
        let (m, addr) = live_monitor();
        m.stop(); // joins the accept thread — must not hang
        // a later connection may be refused or reset; either is fine,
        // the point is stop() returned
        let _ = TcpStream::connect_timeout(&addr, Duration::from_millis(200));
    }

    #[test]
    fn responses_carry_exact_content_length() {
        let r = response(200, "text/plain", "hello\n");
        assert!(r.contains("Content-Length: 6\r\n"), "{r}");
        assert!(r.ends_with("\r\n\r\nhello\n"), "{r}");
        let r = response(503, "application/json", "{}");
        assert!(r.starts_with("HTTP/1.1 503 Service Unavailable"), "{r}");
    }
}
