//! Heartbeats, the stall watchdog, and the flight recorder.
//!
//! Every shard worker holds a [`Pulse`] and touches it once per loop
//! iteration. The loop ingests events with a ≤ 1 ms receive timeout, so
//! a **healthy** shard beats hundreds of times per monitor interval and
//! a shard whose heartbeat is older than one interval is wedged — stuck
//! inside an engine round, deadlocked, or dead. The watchdog check is
//! computed on demand from the atomic beat stamp (no watchdog thread
//! needs to be scheduled for `health()` to tell the truth).
//!
//! The [`FlightRecorder`] is a small bounded ring of structured
//! [`Event`]s — sheds, engine switches, halo spikes, SLO transitions,
//! wedge transitions, panics — that answers "what happened just before
//! it broke?" Events are *derived by the sampler thread from snapshot
//! deltas* (the hot path never pushes an event); the one exception is
//! [`Pulse::panicked`], which runs on a shard's already-cold crash path.
//!
//! Disabled contract: a disabled [`Pulse`] is `Option::None` inside —
//! [`Pulse::touch`] is a branch, [`Pulse::pressure_boost`] returns 0,
//! no clock is read, nothing locks, nothing allocates (proven in
//! `rust/tests/plan_alloc.rs`).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Queue-depth boost an SLO breach injects through
/// [`crate::server::InferenceEngine::note_queue_depth`]: far above any
/// real backlog and any configured `queue_pressure` threshold
/// ([`crate::fleet::AutoConfig`], default 8), so adaptive engines treat
/// a breach exactly like a deep queue — cooldown waived, switch now.
pub const SLO_PRESSURE_BOOST: usize = 1_000_000;

/// What a flight-recorder event records.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Deployment monitor started.
    Launch,
    /// Monitor stopped (clean shutdown marker).
    Shutdown,
    /// Admission rejections observed this tick (value = how many).
    Shed,
    /// Adaptive-engine strategy switches observed this tick.
    EngineSwitch,
    /// Halo traffic this tick spiked far above its moving average.
    HaloSpike,
    /// The SLO transitioned healthy → breached.
    SloBreach,
    /// The SLO transitioned breached → healthy.
    SloRecovered,
    /// A shard's heartbeat went stale (wedged/stalled/dead).
    ShardWedged,
    /// A previously-wedged shard resumed beating.
    ShardRecovered,
    /// A shard worker panicked (recorded from its crash path).
    ShardPanic,
}

impl EventKind {
    /// Stable lowercase mnemonic (JSON `kind` field, post-mortem lines).
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Launch => "launch",
            EventKind::Shutdown => "shutdown",
            EventKind::Shed => "shed",
            EventKind::EngineSwitch => "engine_switch",
            EventKind::HaloSpike => "halo_spike",
            EventKind::SloBreach => "slo_breach",
            EventKind::SloRecovered => "slo_recovered",
            EventKind::ShardWedged => "shard_wedged",
            EventKind::ShardRecovered => "shard_recovered",
            EventKind::ShardPanic => "shard_panic",
        }
    }
}

/// One flight-recorder breadcrumb.
#[derive(Debug, Clone)]
pub struct Event {
    /// Milliseconds since the monitor epoch.
    pub at_ms: u64,
    /// Shard the event concerns (`None` = deployment-wide).
    pub shard: Option<usize>,
    pub kind: EventKind,
    /// Human detail ("12 rejections", the panic message, …).
    pub detail: String,
}

impl Event {
    /// Stable one-line JSON encoding (the `/events` endpoint emits one
    /// per line).
    pub fn to_json(&self) -> String {
        let shard = match self.shard {
            Some(s) => s.to_string(),
            None => "null".to_string(),
        };
        format!(
            "{{\"at_ms\":{},\"shard\":{shard},\"kind\":\"{}\",\
             \"detail\":\"{}\"}}",
            self.at_ms,
            self.kind.name(),
            self.detail.replace('\\', "\\\\").replace('"', "\\\""),
        )
    }

    /// One post-mortem report line.
    pub fn render(&self) -> String {
        let who = match self.shard {
            Some(s) => format!("shard {s}"),
            None => "fleet".to_string(),
        };
        format!(
            "  +{:>8.3}s  {:<9} {:<15} {}",
            self.at_ms as f64 / 1e3,
            who,
            self.kind.name(),
            self.detail
        )
    }
}

/// Bounded event ring (oldest overwritten) with an exact total count.
#[derive(Debug)]
pub struct FlightRecorder {
    cap: usize,
    events: VecDeque<Event>,
    total: u64,
}

impl FlightRecorder {
    pub fn new(cap: usize) -> FlightRecorder {
        let cap = cap.max(1);
        FlightRecorder { cap, events: VecDeque::with_capacity(cap), total: 0 }
    }

    pub fn push(&mut self, e: Event) {
        if self.events.len() == self.cap {
            self.events.pop_front();
        }
        self.events.push_back(e);
        self.total += 1;
    }

    /// Retained events, oldest first.
    pub fn events(&self) -> Vec<Event> {
        self.events.iter().cloned().collect()
    }

    /// Events ever recorded (≥ retained).
    pub fn total(&self) -> u64 {
        self.total
    }

    /// The post-mortem report: every retained breadcrumb in order, with
    /// how many older ones the ring dropped.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let dropped = self.total - self.events.len() as u64;
        out.push_str(&format!(
            "flight recorder — {} event(s) retained ({} dropped):\n",
            self.events.len(),
            dropped
        ));
        for e in &self.events {
            out.push_str(&e.render());
            out.push('\n');
        }
        out
    }
}

/// Shared heartbeat + pressure state between one shard worker and the
/// monitor. The shard side only ever touches atomics.
#[derive(Debug)]
pub(crate) struct PulseShared {
    pub(crate) shard: usize,
    /// Monitor epoch (every beat stamp is relative to it).
    pub(crate) epoch: Instant,
    /// Last heartbeat, ms since epoch.
    pub(crate) beat_ms: AtomicU64,
    /// Deployment-wide SLO breach flag, written by the sampler.
    pub(crate) breached: Arc<AtomicBool>,
    /// Whether a breach should be fed to the engines as queue pressure.
    pub(crate) pressure: bool,
    /// Any-shard-panicked flag (read by `health()`).
    pub(crate) panic_flag: Arc<AtomicBool>,
    /// The deployment's flight recorder (panic breadcrumbs only — the
    /// hot path never locks this).
    pub(crate) recorder: Arc<Mutex<FlightRecorder>>,
}

/// A shard worker's heartbeat handle. Disabled (the default everywhere
/// `[monitor]` is absent) it is a no-op: no clock, no lock, no
/// allocation — just an `Option` branch.
#[derive(Debug, Clone, Default)]
pub struct Pulse {
    pub(crate) inner: Option<Arc<PulseShared>>,
}

impl Pulse {
    /// The inert pulse every unmonitored worker gets.
    pub fn disabled() -> Pulse {
        Pulse { inner: None }
    }

    /// Whether beats are actually recorded.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Stamp a heartbeat (called once per shard-loop iteration).
    #[inline]
    pub fn touch(&self) {
        if let Some(p) = &self.inner {
            p.beat_ms
                .store(p.epoch.elapsed().as_millis() as u64, Ordering::Relaxed);
        }
    }

    /// Extra queue depth to report to the engine this round:
    /// [`SLO_PRESSURE_BOOST`] while the SLO is breached and pressure
    /// feedback is on, else 0 (always 0 when disabled).
    #[inline]
    pub fn pressure_boost(&self) -> usize {
        match &self.inner {
            Some(p) if p.pressure && p.breached.load(Ordering::Relaxed) => {
                SLO_PRESSURE_BOOST
            }
            _ => 0,
        }
    }

    /// Record a worker panic breadcrumb (crash path — cold by
    /// definition, so locking the recorder here is fine).
    pub fn panicked(&self, msg: &str) {
        if let Some(p) = &self.inner {
            p.panic_flag.store(true, Ordering::Relaxed);
            if let Ok(mut rec) = p.recorder.lock() {
                rec.push(Event {
                    at_ms: p.epoch.elapsed().as_millis() as u64,
                    shard: Some(p.shard),
                    kind: EventKind::ShardPanic,
                    detail: msg.to_string(),
                });
            }
        }
    }
}

/// One shard's liveness as of a [`HealthReport`].
#[derive(Debug, Clone)]
pub struct ShardHealth {
    pub id: usize,
    /// Heartbeat age, ms (0 for a shard that just beat).
    pub beat_age_ms: u64,
    /// True when the heartbeat is older than one monitor interval.
    pub wedged: bool,
    /// Cumulative counters, for context.
    pub queries: usize,
    pub rejected: usize,
}

/// The deployment's liveness + SLO verdict, from
/// [`crate::monitor::Monitor::health`] / `GET /health`.
#[derive(Debug, Clone)]
pub struct HealthReport {
    /// When the report was computed, ms since the monitor epoch.
    pub at_ms: u64,
    /// No wedged shard, no recorded panic, no active SLO breach.
    pub healthy: bool,
    /// Any worker panic was ever recorded.
    pub panicked: bool,
    /// SLO verdict (`None` when no `[slo]` section is enabled).
    pub slo: Option<super::slo::SloStatus>,
    pub shards: Vec<ShardHealth>,
}

impl HealthReport {
    /// Stable JSON encoding (the `/health` body).
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(256);
        out.push_str(&format!(
            "{{\"healthy\":{},\"at_ms\":{},\"panicked\":{},\"slo\":{}",
            self.healthy,
            self.at_ms,
            self.panicked,
            match &self.slo {
                Some(s) => s.to_json(),
                None => "null".to_string(),
            }
        ));
        out.push_str(",\"shards\":[");
        for (i, s) in self.shards.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"id\":{},\"beat_age_ms\":{},\"wedged\":{},\
                 \"queries\":{},\"rejected\":{}}}",
                s.id, s.beat_age_ms, s.wedged, s.queries, s.rejected
            ));
        }
        out.push_str("]}");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_pulse_is_inert() {
        let p = Pulse::disabled();
        assert!(!p.enabled());
        p.touch(); // must be a no-op, not a panic
        assert_eq!(p.pressure_boost(), 0);
        p.panicked("nothing listens");
    }

    #[test]
    fn enabled_pulse_beats_and_boosts() {
        let recorder = Arc::new(Mutex::new(FlightRecorder::new(8)));
        let breached = Arc::new(AtomicBool::new(false));
        let panic_flag = Arc::new(AtomicBool::new(false));
        let shared = Arc::new(PulseShared {
            shard: 3,
            epoch: Instant::now(),
            beat_ms: AtomicU64::new(u64::MAX),
            breached: breached.clone(),
            pressure: true,
            panic_flag: panic_flag.clone(),
            recorder: recorder.clone(),
        });
        let p = Pulse { inner: Some(shared.clone()) };
        p.touch();
        assert!(shared.beat_ms.load(Ordering::Relaxed) < 1_000, "fresh beat");
        assert_eq!(p.pressure_boost(), 0, "no breach, no boost");
        breached.store(true, Ordering::Relaxed);
        assert_eq!(p.pressure_boost(), SLO_PRESSURE_BOOST);
        p.panicked("engine round hung");
        assert!(panic_flag.load(Ordering::Relaxed));
        let evs = recorder.lock().unwrap().events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].kind, EventKind::ShardPanic);
        assert_eq!(evs[0].shard, Some(3));
        assert!(evs[0].detail.contains("hung"));
    }

    #[test]
    fn pressure_respects_the_spec_switch() {
        let shared = Arc::new(PulseShared {
            shard: 0,
            epoch: Instant::now(),
            beat_ms: AtomicU64::new(0),
            breached: Arc::new(AtomicBool::new(true)),
            pressure: false, // [slo] pressure = false
            panic_flag: Arc::new(AtomicBool::new(false)),
            recorder: Arc::new(Mutex::new(FlightRecorder::new(4))),
        });
        let p = Pulse { inner: Some(shared) };
        assert_eq!(p.pressure_boost(), 0, "breached but pressure is off");
    }

    #[test]
    fn recorder_ring_bounds_and_renders_in_order() {
        let mut r = FlightRecorder::new(3);
        for i in 0..5u64 {
            r.push(Event {
                at_ms: i * 100,
                shard: Some(i as usize),
                kind: EventKind::Shed,
                detail: format!("{i} rejections"),
            });
        }
        let evs = r.events();
        assert_eq!(evs.len(), 3);
        assert_eq!(r.total(), 5);
        let ats: Vec<u64> = evs.iter().map(|e| e.at_ms).collect();
        assert_eq!(ats, vec![200, 300, 400], "oldest dropped, order kept");
        let report = r.render();
        assert!(report.contains("3 event(s) retained (2 dropped)"), "{report}");
        let p2 = report.find("2 rejections").unwrap();
        let p4 = report.find("4 rejections").unwrap();
        assert!(p2 < p4, "breadcrumbs render in order");
    }

    #[test]
    fn event_json_escapes_and_balances() {
        let e = Event {
            at_ms: 42,
            shard: None,
            kind: EventKind::ShardPanic,
            detail: "say \"boom\"".to_string(),
        };
        let j = e.to_json();
        assert!(j.contains("\\\"boom\\\""), "{j}");
        assert!(j.contains("\"shard\":null"), "{j}");
        assert!(j.contains("\"kind\":\"shard_panic\""), "{j}");
        assert_eq!(j.matches('{').count(), j.matches('}').count());
    }

    #[test]
    fn health_report_json_reflects_wedges() {
        let r = HealthReport {
            at_ms: 500,
            healthy: false,
            panicked: false,
            slo: None,
            shards: vec![
                ShardHealth { id: 0, beat_age_ms: 1, wedged: false, queries: 10,
                              rejected: 0 },
                ShardHealth { id: 1, beat_age_ms: 900, wedged: true, queries: 2,
                              rejected: 5 },
            ],
        };
        let j = r.to_json();
        assert!(j.contains("\"healthy\":false"), "{j}");
        assert!(j.contains("\"slo\":null"), "{j}");
        assert!(j.contains("\"id\":1,\"beat_age_ms\":900,\"wedged\":true"), "{j}");
        assert_eq!(j.matches('{').count(), j.matches('}').count(), "{j}");
        assert_eq!(j.matches('[').count(), j.matches(']').count(), "{j}");
    }
}
