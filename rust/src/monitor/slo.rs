//! SLO evaluation: multi-window burn rates over the fleet history ring.
//!
//! The `[slo]` spec section ([`crate::serve::spec::SloSpec`]) states two
//! objectives — a target-quantile latency bound and an availability
//! target — and this module answers "are we burning the error budget
//! too fast?" the way production SLO alerting does: a breach requires
//! the burn rate to exceed the threshold in **both** a fast window
//! (catches sudden regressions quickly) and a slow window (filters
//! blips), for either objective. One bad tick cannot page anyone, and a
//! slow leak cannot hide behind a calm last second.
//!
//! Burn rate is measured against the error budget `1 − availability`:
//!
//! - **availability burn** over a window =
//!   `(Δrejected / Δ(queries + rejected)) / (1 − availability)` — the
//!   observed failure fraction as a multiple of the sustainable one;
//! - **latency burn** over a window = the fraction of ticks whose
//!   target-quantile latency estimate exceeded the objective, again
//!   divided by the budget. (Tick latency comes from the cumulative
//!   metrics reservoirs — see [`crate::monitor::history::Sample`] — so
//!   it is an estimate of "the deployment's quantile as of that tick",
//!   not a per-window quantile.)
//!
//! Evaluation is pure over `&[Sample]` so it is unit-testable without
//! threads or clocks.

use super::history::Sample;

/// Runtime SLO parameters, lowered from the spec
/// ([`crate::serve::spec::SloSpec::params`]) after validation — every
/// field here can be assumed in-range.
#[derive(Debug, Clone, PartialEq)]
pub struct SloParams {
    /// Latency objective, µs: the target quantile must stay ≤ this.
    pub latency_us: f64,
    /// Which latency quantile the objective targets, in (0, 1).
    pub quantile: f64,
    /// Availability target in (0, 1); error budget = `1 − availability`.
    pub availability: f64,
    /// Fast burn window, ms.
    pub fast_window_ms: u64,
    /// Slow burn window, ms (> fast).
    pub slow_window_ms: u64,
    /// Burn-rate multiple that constitutes a breach (> 1).
    pub burn_threshold: f64,
}

/// Burn rates over one window.
#[derive(Debug, Clone, PartialEq)]
pub struct BurnRates {
    /// Window span this was computed over, ms.
    pub window_ms: u64,
    /// Failure-fraction burn as a multiple of the budget (0 = no
    /// rejections, 1 = exactly on budget).
    pub availability_burn: f64,
    /// Latency-objective burn: fraction of over-objective ticks as a
    /// multiple of the budget.
    pub latency_burn: f64,
}

impl BurnRates {
    fn over(params: &SloParams, samples: &[&Sample], window_ms: u64) -> BurnRates {
        let budget = (1.0 - params.availability).max(1e-12);
        let (mut avail, mut lat) = (0.0, 0.0);
        if let (Some(first), Some(last)) = (samples.first(), samples.last()) {
            let dq = last.snap.queries.saturating_sub(first.snap.queries);
            let dr = last.snap.rejected.saturating_sub(first.snap.rejected);
            if dq + dr > 0 {
                avail = (dr as f64 / (dq + dr) as f64) / budget;
            }
            // ticks past the baseline with a latency estimate
            let measured: Vec<&&Sample> = samples
                .iter()
                .skip(1)
                .filter(|s| s.latency_q_us.is_some())
                .collect();
            if !measured.is_empty() {
                let bad = measured
                    .iter()
                    .filter(|s| s.latency_q_us.unwrap() > params.latency_us)
                    .count();
                lat = (bad as f64 / measured.len() as f64) / budget;
            }
        }
        BurnRates { window_ms, availability_burn: avail, latency_burn: lat }
    }
}

/// The monitor's current SLO verdict, surfaced through
/// [`crate::serve::Serving::health`] and `GET /health`.
#[derive(Debug, Clone, PartialEq)]
pub struct SloStatus {
    /// True when either objective burns past the threshold in **both**
    /// windows.
    pub breached: bool,
    /// Latest target-quantile latency estimate, µs.
    pub latency_q_us: Option<f64>,
    /// The objective the estimate is held against, µs.
    pub objective_us: f64,
    /// Target quantile (so reports can label the number).
    pub quantile: f64,
    pub fast: BurnRates,
    pub slow: BurnRates,
}

impl SloStatus {
    /// Stable one-line JSON encoding for `/health` and the flight
    /// recorder.
    pub fn to_json(&self) -> String {
        let lat = match self.latency_q_us {
            Some(v) if v.is_finite() => format!("{v}"),
            _ => "null".to_string(),
        };
        format!(
            "{{\"breached\":{},\"latency_q_us\":{lat},\"objective_us\":{},\
             \"quantile\":{},\"fast\":{{\"window_ms\":{},\
             \"availability_burn\":{:.4},\"latency_burn\":{:.4}}},\
             \"slow\":{{\"window_ms\":{},\"availability_burn\":{:.4},\
             \"latency_burn\":{:.4}}}}}",
            self.breached,
            self.objective_us,
            self.quantile,
            self.fast.window_ms,
            self.fast.availability_burn,
            self.fast.latency_burn,
            self.slow.window_ms,
            self.slow.availability_burn,
            self.slow.latency_burn,
        )
    }
}

/// Evaluate the SLO over the fleet history ring's retained samples
/// (oldest first), as of `now_ms`.
pub fn evaluate(params: &SloParams, samples: &[&Sample], now_ms: u64) -> SloStatus {
    let in_window = |window_ms: u64| -> Vec<&Sample> {
        let start = now_ms.saturating_sub(window_ms);
        let mut out: Vec<&Sample> = Vec::new();
        for (i, s) in samples.iter().enumerate() {
            if s.at_ms >= start {
                if out.is_empty() && i > 0 {
                    out.push(samples[i - 1]); // baseline for the delta
                }
                out.push(s);
            }
        }
        out
    };
    let fast = BurnRates::over(params, &in_window(params.fast_window_ms),
                               params.fast_window_ms);
    let slow = BurnRates::over(params, &in_window(params.slow_window_ms),
                               params.slow_window_ms);
    let t = params.burn_threshold;
    let breached = (fast.availability_burn > t && slow.availability_burn > t)
        || (fast.latency_burn > t && slow.latency_burn > t);
    SloStatus {
        breached,
        latency_q_us: samples.last().and_then(|s| s.latency_q_us),
        objective_us: params.latency_us,
        quantile: params.quantile,
        fast,
        slow,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Metrics;

    fn params() -> SloParams {
        SloParams {
            latency_us: 1_000.0,
            quantile: 0.95,
            availability: 0.9, // budget = 0.1, so burns are fractions × 10
            fast_window_ms: 200,
            slow_window_ms: 1_000,
            burn_threshold: 2.0,
        }
    }

    fn sample(at_ms: u64, queries: usize, rejected: usize,
              lat_us: Option<f64>) -> Sample {
        let m = Metrics::new_shard(0);
        for _ in 0..queries {
            m.record_query(lat_us.unwrap_or(100.0), 1.0, 1);
        }
        for _ in 0..rejected {
            m.record_rejected();
        }
        Sample { at_ms, snap: m.snapshot(), latency_q_us: lat_us }
    }

    #[test]
    fn healthy_traffic_does_not_breach() {
        let s: Vec<Sample> = (0..12u64)
            .map(|t| sample(t * 100, (t as usize + 1) * 10, 0, Some(200.0)))
            .collect();
        let refs: Vec<&Sample> = s.iter().collect();
        let st = evaluate(&params(), &refs, 1_100);
        assert!(!st.breached);
        assert_eq!(st.fast.availability_burn, 0.0);
        assert_eq!(st.fast.latency_burn, 0.0);
        assert_eq!(st.latency_q_us, Some(200.0));
    }

    #[test]
    fn sustained_shedding_breaches_both_windows() {
        // half of all arrivals rejected, for the whole slow window:
        // failure fraction 0.5 / budget 0.1 = burn 5 > threshold 2
        let s: Vec<Sample> = (0..12u64)
            .map(|t| {
                sample(t * 100, (t as usize + 1) * 5, (t as usize + 1) * 5,
                       Some(200.0))
            })
            .collect();
        let refs: Vec<&Sample> = s.iter().collect();
        let st = evaluate(&params(), &refs, 1_100);
        assert!(st.breached, "{st:?}");
        assert!(st.fast.availability_burn > 2.0);
        assert!(st.slow.availability_burn > 2.0);
    }

    #[test]
    fn a_blip_in_the_fast_window_alone_does_not_breach() {
        // rejections only in the final 200 ms: the fast window burns hot
        // but the slow window (mostly clean) stays under threshold
        let mut s: Vec<Sample> = Vec::new();
        for t in 0..10u64 {
            s.push(sample(t * 100, (t as usize + 1) * 100, 0, Some(200.0)));
        }
        // final tick: 5 new queries, 20 new rejections
        s.push(sample(1_000, 1_005, 20, Some(200.0)));
        let refs: Vec<&Sample> = s.iter().collect();
        let st = evaluate(&params(), &refs, 1_000);
        assert!(st.fast.availability_burn > 2.0, "{:?}", st.fast);
        assert!(st.slow.availability_burn < 2.0, "{:?}", st.slow);
        assert!(!st.breached, "one window alone must not page");
    }

    #[test]
    fn sustained_slow_latency_breaches() {
        // every tick's quantile estimate sits above the 1 ms objective:
        // bad-tick fraction 1.0 / budget 0.1 = burn 10
        let s: Vec<Sample> = (0..12u64)
            .map(|t| sample(t * 100, (t as usize + 1) * 10, 0, Some(5_000.0)))
            .collect();
        let refs: Vec<&Sample> = s.iter().collect();
        let st = evaluate(&params(), &refs, 1_100);
        assert!(st.breached);
        assert!(st.fast.latency_burn > 2.0);
        assert!(st.slow.latency_burn > 2.0);
        assert_eq!(st.fast.availability_burn, 0.0, "objectives independent");
    }

    #[test]
    fn empty_history_is_healthy_and_json_is_balanced() {
        let st = evaluate(&params(), &[], 0);
        assert!(!st.breached);
        assert_eq!(st.latency_q_us, None);
        let j = st.to_json();
        assert!(j.contains("\"breached\":false"), "{j}");
        assert!(j.contains("\"latency_q_us\":null"), "{j}");
        assert_eq!(j.matches('{').count(), j.matches('}').count(), "{j}");
    }
}
