//! Fixed-capacity time-series rings of metric snapshots.
//!
//! The sampling thread ([`crate::monitor::Monitor`]) appends one
//! [`Sample`] per shard per tick — a timestamped [`Snapshot`] of the
//! shard's cumulative counters plus the deployment's target-quantile
//! latency — into a [`HistoryRing`] that overwrites its oldest entry
//! past capacity. Everything windowed (`grannite top` columns, the SLO
//! burn rates in [`crate::monitor::slo`]) is derived from **deltas
//! between ring entries**, so the rings are the single source of "what
//! happened over the last N seconds" and the hot path never computes a
//! rate.

use std::collections::VecDeque;

use crate::metrics::Snapshot;

/// One sampler tick for one sink: cumulative counters at a point in
/// time, plus the latency quantile the SLO objective targets (pooled
/// over the deployment for the fleet ring, per-sink otherwise).
#[derive(Debug, Clone)]
pub struct Sample {
    /// Milliseconds since the monitor epoch.
    pub at_ms: u64,
    /// Cumulative counters at this tick (not a delta).
    pub snap: Snapshot,
    /// The SLO target-quantile latency estimate at this tick, µs
    /// (`None` before any query completed).
    pub latency_q_us: Option<f64>,
}

/// Bounded append-only ring of [`Sample`]s, oldest overwritten.
#[derive(Debug)]
pub struct HistoryRing {
    cap: usize,
    samples: VecDeque<Sample>,
    /// Ticks ever pushed (so "how much history fell off" is knowable).
    total: u64,
}

impl HistoryRing {
    /// A ring retaining at most `cap` samples (`cap` ≥ 2 enforced: one
    /// sample yields no delta).
    pub fn new(cap: usize) -> HistoryRing {
        let cap = cap.max(2);
        HistoryRing { cap, samples: VecDeque::with_capacity(cap), total: 0 }
    }

    /// Append one sample, dropping the oldest past capacity.
    pub fn push(&mut self, s: Sample) {
        if self.samples.len() == self.cap {
            self.samples.pop_front();
        }
        self.samples.push_back(s);
        self.total += 1;
    }

    /// Newest sample, if any tick ever ran.
    pub fn latest(&self) -> Option<&Sample> {
        self.samples.back()
    }

    /// Retained samples, oldest first.
    pub fn samples(&self) -> impl Iterator<Item = &Sample> {
        self.samples.iter()
    }

    /// Retained sample count (≤ capacity).
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True before the first tick.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Ticks ever pushed (≥ [`HistoryRing::len`]).
    pub fn total_pushed(&self) -> u64 {
        self.total
    }

    /// The retained samples whose timestamp falls inside the trailing
    /// `window_ms` ending at `now_ms`, oldest first. The sample
    /// immediately *preceding* the window is included when available so
    /// delta rates cover the full span (a window needs a baseline).
    pub fn window(&self, now_ms: u64, window_ms: u64) -> Vec<&Sample> {
        let start = now_ms.saturating_sub(window_ms);
        let mut out: Vec<&Sample> = Vec::new();
        for (i, s) in self.samples.iter().enumerate() {
            if s.at_ms >= start {
                // include the baseline sample just before the cutoff
                if out.is_empty() && i > 0 {
                    out.push(&self.samples[i - 1]);
                }
                out.push(s);
            }
        }
        out
    }
}

/// Delta-derived rates over a run of samples — what `grannite top`
/// renders per shard and per fleet, and what the SLO windows consume.
#[derive(Debug, Clone, PartialEq)]
pub struct WindowRates {
    /// Wall span the deltas cover, ms.
    pub span_ms: u64,
    /// Samples the window held (including the baseline).
    pub ticks: usize,
    /// Queries answered per second over the span.
    pub qps: f64,
    /// Fraction of arrivals rejected over the span
    /// (`Δrejected / Δ(queries + rejected)`; 0 with no arrivals).
    pub shed_rate: f64,
    /// `Δrecomputed_rows / Δeligible_rows` over the span (0 with no
    /// delta-aware rounds).
    pub recompute_ratio: f64,
    /// Halo bytes shipped per second over the span.
    pub halo_bps: f64,
    /// Latency percentiles at the window's newest tick, µs (cumulative
    /// reservoir estimates — see [`crate::metrics::SAMPLE_CAP`]).
    pub p50_us: Option<f64>,
    pub p95_us: Option<f64>,
    pub p99_us: Option<f64>,
}

impl WindowRates {
    /// Rates over `samples` (oldest first, as [`HistoryRing::window`]
    /// returns them). `None` with fewer than two samples — one point
    /// has no delta.
    pub fn over(samples: &[&Sample]) -> Option<WindowRates> {
        let (first, last) = match (samples.first(), samples.last()) {
            (Some(f), Some(l)) if samples.len() >= 2 => (*f, *l),
            _ => return None,
        };
        let span_ms = last.at_ms.saturating_sub(first.at_ms).max(1);
        let span_s = span_ms as f64 / 1e3;
        let dq = last.snap.queries.saturating_sub(first.snap.queries);
        let dr = last.snap.rejected.saturating_sub(first.snap.rejected);
        let arrivals = dq + dr;
        let d_elig =
            last.snap.eligible_rows.saturating_sub(first.snap.eligible_rows);
        let d_rec = last
            .snap
            .recomputed_rows
            .saturating_sub(first.snap.recomputed_rows);
        let d_halo = last.snap.halo_bytes.saturating_sub(first.snap.halo_bytes);
        let lat = last.snap.latency.as_ref();
        Some(WindowRates {
            span_ms,
            ticks: samples.len(),
            qps: dq as f64 / span_s,
            shed_rate: if arrivals == 0 {
                0.0
            } else {
                dr as f64 / arrivals as f64
            },
            recompute_ratio: if d_elig == 0 {
                0.0
            } else {
                d_rec as f64 / d_elig as f64
            },
            halo_bps: d_halo as f64 / span_s,
            p50_us: lat.map(|l| l.p50),
            p95_us: lat.map(|l| l.p95),
            p99_us: lat.map(|l| l.p99),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Metrics;

    fn sample(at_ms: u64, queries: usize, rejected: usize) -> Sample {
        let m = Metrics::new_shard(0);
        for _ in 0..queries {
            m.record_query(100.0, 1.0, 1);
        }
        for _ in 0..rejected {
            m.record_rejected();
        }
        Sample { at_ms, snap: m.snapshot(), latency_q_us: Some(100.0) }
    }

    #[test]
    fn ring_bounds_storage_and_keeps_newest() {
        let mut r = HistoryRing::new(4);
        for t in 0..10u64 {
            r.push(sample(t * 100, t as usize, 0));
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.total_pushed(), 10);
        assert_eq!(r.latest().unwrap().at_ms, 900);
        let ats: Vec<u64> = r.samples().map(|s| s.at_ms).collect();
        assert_eq!(ats, vec![600, 700, 800, 900], "oldest overwritten");
    }

    #[test]
    fn window_includes_the_baseline_sample() {
        let mut r = HistoryRing::new(16);
        for t in 0..8u64 {
            r.push(sample(t * 100, t as usize, 0));
        }
        // trailing 250 ms at t=700 covers 500..=700; the baseline at 400
        // rides along so the delta spans the full window
        let w = r.window(700, 250);
        let ats: Vec<u64> = w.iter().map(|s| s.at_ms).collect();
        assert_eq!(ats, vec![400, 500, 600, 700]);
        // a window wider than history returns everything, no baseline
        assert_eq!(r.window(700, 10_000).len(), 8);
    }

    #[test]
    fn window_rates_are_delta_derived() {
        // 10 queries + 10 rejections arrive over exactly one second
        let a = sample(1_000, 5, 0);
        let b = sample(2_000, 15, 10);
        let w = WindowRates::over(&[&a, &b]).unwrap();
        assert_eq!(w.span_ms, 1_000);
        assert_eq!(w.ticks, 2);
        assert!((w.qps - 10.0).abs() < 1e-9, "qps {}", w.qps);
        assert!((w.shed_rate - 0.5).abs() < 1e-9, "shed {}", w.shed_rate);
        assert_eq!(w.p50_us, Some(100.0));
        // one sample has no delta
        assert!(WindowRates::over(&[&a]).is_none());
        assert!(WindowRates::over(&[]).is_none());
    }

    #[test]
    fn idle_window_reads_zero_not_nan() {
        let a = sample(0, 3, 0);
        let b = sample(500, 3, 0);
        let w = WindowRates::over(&[&a, &b]).unwrap();
        assert_eq!(w.qps, 0.0);
        assert_eq!(w.shed_rate, 0.0);
        assert_eq!(w.recompute_ratio, 0.0);
        assert_eq!(w.halo_bps, 0.0);
    }
}
