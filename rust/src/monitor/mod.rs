//! `monitor` — the operational surface: history rings, SLO burn-rate
//! monitor, health watchdog, flight recorder, and scrape endpoint.
//!
//! PR 6 made a single query observable ([`crate::telemetry`]); this
//! module answers the operator's questions — *is this deployment
//! healthy right now, is it meeting its latency objective, and what
//! happened just before that shard wedged?* One [`Monitor`] per
//! deployment (created by [`crate::serve::Deployment::launch`] when the
//! spec's `[monitor]`/`[slo]` sections ask for it) runs a sampling
//! thread that, every `interval`:
//!
//! 1. snapshots every shard's [`crate::metrics::Metrics`] sink into
//!    per-shard and fleet [`history::HistoryRing`]s (windowed QPS /
//!    shed rate / recompute ratio / latency percentiles derive from
//!    ring deltas — see [`history::WindowRates`]),
//! 2. evaluates the `[slo]` objectives with fast/slow multi-window burn
//!    rates ([`slo::evaluate`]) and feeds an active breach back to the
//!    shard engines as queue pressure ([`health::Pulse::pressure_boost`]),
//! 3. derives flight-recorder breadcrumbs from the snapshot deltas
//!    (sheds, engine switches, halo spikes, SLO and wedge transitions —
//!    the hot path never pushes an event),
//! 4. checks each shard's heartbeat ([`health::Pulse`]) against the
//!    stall watchdog: one missed interval flags the shard wedged.
//!
//! A `[monitor] addr` additionally binds a dependency-free
//! `std::net::TcpListener` scrape endpoint ([`http`]) serving
//! `GET /metrics` (Prometheus text), `/health` (JSON liveness + SLO
//! status, 503 on breach/wedge), `/traces` and `/events` (JSON lines).
//!
//! Overhead contract, same as telemetry: always compiled, off by
//! default. A disabled [`Monitor`] is `Option::None` inside — workers
//! get a disabled [`Pulse`] whose every call is a branch (no clock, no
//! lock, no allocation; proven in `rust/tests/plan_alloc.rs`), and no
//! thread spawns. Enabled, the only hot-path additions are one relaxed
//! atomic store per shard-loop iteration (the heartbeat) and one
//! relaxed atomic load per inference round (the pressure check).

pub mod health;
pub mod history;
pub mod http;
pub mod slo;

pub use health::{
    Event, EventKind, FlightRecorder, HealthReport, Pulse, ShardHealth,
    SLO_PRESSURE_BOOST,
};
pub use history::{HistoryRing, Sample, WindowRates};
pub use slo::{BurnRates, SloParams, SloStatus};

use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::metrics::{Metrics, Snapshot};
use crate::telemetry::Telemetry;

/// Runtime monitor configuration, lowered from the spec
/// ([`crate::serve::spec::DeploymentSpec::monitor_config`]).
#[derive(Debug, Clone)]
pub struct MonitorConfig {
    /// Sampling interval; also the stall-watchdog threshold.
    pub interval: Duration,
    /// Samples retained per history ring.
    pub history: usize,
    /// SLO objectives (`None` = liveness-only monitoring).
    pub slo: Option<SloParams>,
    /// Feed an active SLO breach to engines as queue pressure.
    pub pressure: bool,
    /// Flight-recorder event capacity.
    pub events: usize,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        MonitorConfig {
            interval: Duration::from_millis(250),
            history: 240,
            slo: None,
            pressure: true,
            events: 128,
        }
    }
}

/// One registered shard: its metrics sink, heartbeat state, and ring.
struct ShardEntry {
    id: usize,
    metrics: Arc<Metrics>,
    pulse: Arc<health::PulseShared>,
    ring: HistoryRing,
}

/// Per-shard sampler memory for delta-derived events.
#[derive(Debug, Clone, Default)]
struct ShardTick {
    last_rejected: usize,
    last_switches: usize,
    last_halo: usize,
    halo_ewma: f64,
    wedged: bool,
}

struct Inner {
    config: MonitorConfig,
    epoch: Instant,
    /// Latency quantile each tick estimates (the SLO target, or p95).
    target_q: f64,
    shards: Mutex<Vec<ShardEntry>>,
    fleet_ring: Mutex<HistoryRing>,
    ticks: Mutex<Vec<ShardTick>>,
    /// Last SLO verdict (for breach/recovery transition events).
    slo_breached_last: AtomicBool,
    recorder: Arc<Mutex<FlightRecorder>>,
    breached: Arc<AtomicBool>,
    panicked: Arc<AtomicBool>,
    telemetry: Mutex<Arc<Telemetry>>,
    listener: Mutex<Option<TcpListener>>,
    bound: Mutex<Option<SocketAddr>>,
    threads: Mutex<Vec<JoinHandle<()>>>,
    started: AtomicBool,
    stopping: AtomicBool,
    stopped: AtomicBool,
}

/// The deployment monitor handle. Cheap to clone (an `Option<Arc>`);
/// [`Monitor::disabled`] is the inert default every unmonitored
/// deployment carries.
#[derive(Clone, Default)]
pub struct Monitor {
    inner: Option<Arc<Inner>>,
}

impl std::fmt::Debug for Monitor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.inner {
            None => write!(f, "Monitor(disabled)"),
            Some(i) => f
                .debug_struct("Monitor")
                .field("interval", &i.config.interval)
                .field("history", &i.config.history)
                .field("slo", &i.config.slo.is_some())
                .field("addr", &*i.bound.lock().unwrap())
                .finish(),
        }
    }
}

impl Monitor {
    /// The off-by-default monitor: no thread, no clock, inert pulses.
    pub fn disabled() -> Monitor {
        Monitor { inner: None }
    }

    /// A live monitor (no thread yet — see [`Monitor::start`]).
    pub fn new(config: MonitorConfig) -> Monitor {
        let target_q = config.slo.as_ref().map(|s| s.quantile).unwrap_or(0.95);
        let history = config.history.max(2);
        let events = config.events.max(1);
        Monitor {
            inner: Some(Arc::new(Inner {
                target_q,
                epoch: Instant::now(),
                shards: Mutex::new(Vec::new()),
                fleet_ring: Mutex::new(HistoryRing::new(history)),
                ticks: Mutex::new(Vec::new()),
                slo_breached_last: AtomicBool::new(false),
                recorder: Arc::new(Mutex::new(FlightRecorder::new(events))),
                breached: Arc::new(AtomicBool::new(false)),
                panicked: Arc::new(AtomicBool::new(false)),
                telemetry: Mutex::new(Telemetry::disabled()),
                listener: Mutex::new(None),
                bound: Mutex::new(None),
                threads: Mutex::new(Vec::new()),
                started: AtomicBool::new(false),
                stopping: AtomicBool::new(false),
                stopped: AtomicBool::new(false),
                config,
            })),
        }
    }

    /// Whether anything is actually monitored.
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Milliseconds since the monitor epoch (0 when disabled).
    pub fn now_ms(&self) -> u64 {
        match &self.inner {
            Some(i) => i.epoch.elapsed().as_millis() as u64,
            None => 0,
        }
    }

    fn interval_ms(i: &Inner) -> u64 {
        (i.config.interval.as_millis() as u64).max(1)
    }

    /// Register a shard's metrics sink; returns the heartbeat handle its
    /// worker loop will touch. Called by [`crate::fleet::ShardWorker`]
    /// at spawn (so registration order is deterministic); a disabled
    /// monitor hands back a disabled pulse.
    pub fn register_shard(&self, id: usize, metrics: Arc<Metrics>) -> Pulse {
        let Some(i) = &self.inner else {
            return Pulse::disabled();
        };
        let shared = Arc::new(health::PulseShared {
            shard: id,
            epoch: i.epoch,
            // the first "beat" is registration time, so a shard that
            // wedges before its first loop iteration is still caught
            beat_ms: AtomicU64::new(i.epoch.elapsed().as_millis() as u64),
            breached: Arc::clone(&i.breached),
            pressure: i.config.pressure,
            panic_flag: Arc::clone(&i.panicked),
            recorder: Arc::clone(&i.recorder),
        });
        i.shards.lock().unwrap().push(ShardEntry {
            id,
            metrics,
            pulse: Arc::clone(&shared),
            ring: HistoryRing::new(i.config.history.max(2)),
        });
        i.ticks.lock().unwrap().push(ShardTick::default());
        Pulse { inner: Some(shared) }
    }

    /// Attach the deployment's telemetry hub so `/metrics` and
    /// `/traces` can serve calibration and trace data.
    pub fn set_telemetry(&self, t: Arc<Telemetry>) {
        if let Some(i) = &self.inner {
            *i.telemetry.lock().unwrap() = t;
        }
    }

    /// Bind the scrape endpoint (called before workers spawn so a bad
    /// address fails the launch cleanly; port 0 picks a free port).
    /// The accept loop starts with [`Monitor::start`].
    pub fn bind(&self, addr: &str) -> Result<SocketAddr> {
        let i = self
            .inner
            .as_ref()
            .context("cannot bind a scrape endpoint on a disabled monitor")?;
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding monitor endpoint {addr}"))?;
        let bound = listener.local_addr()?;
        *i.listener.lock().unwrap() = Some(listener);
        *i.bound.lock().unwrap() = Some(bound);
        Ok(bound)
    }

    /// The bound scrape address, if [`Monitor::bind`] succeeded.
    pub fn addr(&self) -> Option<SocketAddr> {
        self.inner.as_ref().and_then(|i| *i.bound.lock().unwrap())
    }

    /// Start the sampling thread (and the accept loop, when bound).
    /// Idempotent; a disabled monitor does nothing.
    pub fn start(&self) {
        let Some(i) = &self.inner else { return };
        if i.started.swap(true, Ordering::SeqCst) {
            return;
        }
        i.recorder.lock().unwrap().push(Event {
            at_ms: i.epoch.elapsed().as_millis() as u64,
            shard: None,
            kind: EventKind::Launch,
            detail: format!(
                "monitor started ({} shard(s), interval {:?})",
                i.shards.lock().unwrap().len(),
                i.config.interval
            ),
        });
        let mut threads = i.threads.lock().unwrap();
        let sampler = self.clone();
        threads.push(std::thread::spawn(move || sampler.sampler_loop()));
        let http_listener = i.listener.lock().unwrap().take();
        if let Some(listener) = http_listener {
            let m = self.clone();
            threads.push(http::spawn(m, listener));
        }
    }

    fn sampler_loop(&self) {
        let Some(i) = &self.inner else { return };
        let interval = i.config.interval.max(Duration::from_millis(1));
        while !i.stopping.load(Ordering::SeqCst) {
            // sleep in short slices so stop() is prompt even with a
            // multi-second interval
            let mut slept = Duration::ZERO;
            while slept < interval && !i.stopping.load(Ordering::SeqCst) {
                let slice = (interval - slept).min(Duration::from_millis(25));
                std::thread::sleep(slice);
                slept += slice;
            }
            if i.stopping.load(Ordering::SeqCst) {
                break;
            }
            self.sample_now();
        }
    }

    /// Take one sampler tick immediately: snapshot every shard, push
    /// ring samples, derive flight-recorder events, re-evaluate the
    /// SLO. The sampling thread calls this every interval; tests and
    /// `grannite top` may call it directly.
    pub fn sample_now(&self) {
        let Some(i) = &self.inner else { return };
        let now_ms = i.epoch.elapsed().as_millis() as u64;
        let interval_ms = Self::interval_ms(i);
        let mut events: Vec<Event> = Vec::new();

        let mut shards = i.shards.lock().unwrap();
        let mut ticks = i.ticks.lock().unwrap();
        let fleet_snap = Metrics::merged(shards.iter().map(|e| e.metrics.as_ref()));
        let fleet_q = Metrics::pooled_latency_quantile(
            shards.iter().map(|e| e.metrics.as_ref()),
            i.target_q,
        );
        for (e, t) in shards.iter_mut().zip(ticks.iter_mut()) {
            let snap = e.metrics.snapshot();
            // shed burst: rejections since the last tick
            let d_rej = snap.rejected.saturating_sub(t.last_rejected);
            if d_rej > 0 {
                events.push(Event {
                    at_ms: now_ms,
                    shard: Some(e.id),
                    kind: EventKind::Shed,
                    detail: format!(
                        "{d_rej} rejection(s) this tick ({} total)",
                        snap.rejected
                    ),
                });
            }
            t.last_rejected = snap.rejected;
            // adaptive-engine strategy switches
            let d_sw = snap.engine_switches.saturating_sub(t.last_switches);
            if d_sw > 0 {
                events.push(Event {
                    at_ms: now_ms,
                    shard: Some(e.id),
                    kind: EventKind::EngineSwitch,
                    detail: format!(
                        "{d_sw} strategy switch(es) → {}",
                        snap.active_strategy.as_deref().unwrap_or("?")
                    ),
                });
            }
            t.last_switches = snap.engine_switches;
            // halo spike: this tick's boundary traffic far above its
            // moving average (and big enough to matter)
            let d_halo = snap.halo_bytes.saturating_sub(t.last_halo) as f64;
            if t.halo_ewma > 0.0 && d_halo > 4.0 * t.halo_ewma && d_halo > 4096.0
            {
                events.push(Event {
                    at_ms: now_ms,
                    shard: Some(e.id),
                    kind: EventKind::HaloSpike,
                    detail: format!(
                        "{} halo bytes this tick (moving avg {})",
                        d_halo as usize, t.halo_ewma as usize
                    ),
                });
            }
            t.halo_ewma = if t.halo_ewma == 0.0 {
                d_halo
            } else {
                0.8 * t.halo_ewma + 0.2 * d_halo
            };
            t.last_halo = snap.halo_bytes;
            // stall-watchdog transitions
            let beat = e.pulse.beat_ms.load(Ordering::Relaxed);
            let age = now_ms.saturating_sub(beat);
            let wedged = age > interval_ms;
            if wedged && !t.wedged {
                events.push(Event {
                    at_ms: now_ms,
                    shard: Some(e.id),
                    kind: EventKind::ShardWedged,
                    detail: format!("heartbeat {age} ms stale (> {interval_ms})"),
                });
            } else if !wedged && t.wedged {
                events.push(Event {
                    at_ms: now_ms,
                    shard: Some(e.id),
                    kind: EventKind::ShardRecovered,
                    detail: "heartbeat resumed".to_string(),
                });
            }
            t.wedged = wedged;
            let latency_q_us = e.metrics.latency_quantile(i.target_q);
            e.ring.push(Sample { at_ms: now_ms, snap, latency_q_us });
        }
        drop(ticks);
        drop(shards);

        let mut fleet_ring = i.fleet_ring.lock().unwrap();
        fleet_ring.push(Sample {
            at_ms: now_ms,
            snap: fleet_snap,
            latency_q_us: fleet_q,
        });
        // SLO verdict over the fleet ring, with transition breadcrumbs
        if let Some(params) = &i.config.slo {
            let samples: Vec<&Sample> = fleet_ring.samples().collect();
            let status = slo::evaluate(params, &samples, now_ms);
            let was = i.slo_breached_last.swap(status.breached, Ordering::SeqCst);
            i.breached.store(status.breached, Ordering::Relaxed);
            if status.breached && !was {
                events.push(Event {
                    at_ms: now_ms,
                    shard: None,
                    kind: EventKind::SloBreach,
                    detail: format!(
                        "burn fast {:.1}×/{:.1}× slow {:.1}×/{:.1}× \
                         (avail/latency, threshold {:.1}×)",
                        status.fast.availability_burn,
                        status.fast.latency_burn,
                        status.slow.availability_burn,
                        status.slow.latency_burn,
                        params.burn_threshold
                    ),
                });
            } else if !status.breached && was {
                events.push(Event {
                    at_ms: now_ms,
                    shard: None,
                    kind: EventKind::SloRecovered,
                    detail: "burn rates back under threshold".to_string(),
                });
            }
        }
        drop(fleet_ring);

        if !events.is_empty() {
            let mut rec = i.recorder.lock().unwrap();
            for e in events {
                rec.push(e);
            }
        }
    }

    /// The deployment's liveness + SLO verdict, computed on demand —
    /// heartbeat staleness is read directly from the atomic stamps, so
    /// a wedged shard is visible within one interval even between
    /// sampler ticks. `None` when disabled.
    pub fn health(&self) -> Option<HealthReport> {
        let i = self.inner.as_ref()?;
        let now_ms = i.epoch.elapsed().as_millis() as u64;
        let interval_ms = Self::interval_ms(i);
        let shards = i.shards.lock().unwrap();
        let mut any_wedged = false;
        let shard_health: Vec<ShardHealth> = shards
            .iter()
            .map(|e| {
                let beat = e.pulse.beat_ms.load(Ordering::Relaxed);
                let age = now_ms.saturating_sub(beat);
                let wedged = age > interval_ms;
                any_wedged |= wedged;
                let snap = e.metrics.snapshot();
                ShardHealth {
                    id: e.id,
                    beat_age_ms: age,
                    wedged,
                    queries: snap.queries,
                    rejected: snap.rejected,
                }
            })
            .collect();
        drop(shards);
        let slo_status = self.slo_status();
        let breached = slo_status.as_ref().map(|s| s.breached).unwrap_or(false);
        let panicked = i.panicked.load(Ordering::Relaxed);
        Some(HealthReport {
            at_ms: now_ms,
            healthy: !any_wedged && !panicked && !breached,
            panicked,
            slo: slo_status,
            shards: shard_health,
        })
    }

    /// The current SLO verdict (`None` when disabled or no `[slo]`).
    pub fn slo_status(&self) -> Option<SloStatus> {
        let i = self.inner.as_ref()?;
        let params = i.config.slo.as_ref()?;
        let now_ms = i.epoch.elapsed().as_millis() as u64;
        let ring = i.fleet_ring.lock().unwrap();
        let samples: Vec<&Sample> = ring.samples().collect();
        Some(slo::evaluate(params, &samples, now_ms))
    }

    /// Flight-recorder breadcrumbs, oldest first.
    pub fn events(&self) -> Vec<Event> {
        match &self.inner {
            Some(i) => i.recorder.lock().unwrap().events(),
            None => Vec::new(),
        }
    }

    /// The post-mortem report: health summary + every retained
    /// breadcrumb in order. Printed by the topologies when shutdown
    /// surfaces a worker failure, and servable on demand.
    pub fn post_mortem(&self) -> String {
        let Some(i) = &self.inner else {
            return "monitor disabled — no flight data".to_string();
        };
        let mut out = String::new();
        if let Some(h) = self.health() {
            out.push_str(&format!(
                "post-mortem at +{:.3}s — healthy: {}, panicked: {}, \
                 slo breached: {}\n",
                h.at_ms as f64 / 1e3,
                h.healthy,
                h.panicked,
                h.slo.as_ref().map(|s| s.breached).unwrap_or(false)
            ));
            for s in &h.shards {
                out.push_str(&format!(
                    "  shard {}: beat {} ms ago{}, {} queries, {} rejected\n",
                    s.id,
                    s.beat_age_ms,
                    if s.wedged { " (WEDGED)" } else { "" },
                    s.queries,
                    s.rejected
                ));
            }
        }
        out.push_str(&i.recorder.lock().unwrap().render());
        out
    }

    /// The fleet history ring's retained samples, oldest first.
    pub fn fleet_history(&self) -> Vec<Sample> {
        match &self.inner {
            Some(i) => i.fleet_ring.lock().unwrap().samples().cloned().collect(),
            None => Vec::new(),
        }
    }

    /// Per-shard history rings: `(shard id, samples oldest first)`.
    pub fn shard_histories(&self) -> Vec<(usize, Vec<Sample>)> {
        match &self.inner {
            Some(i) => i
                .shards
                .lock()
                .unwrap()
                .iter()
                .map(|e| (e.id, e.ring.samples().cloned().collect()))
                .collect(),
            None => Vec::new(),
        }
    }

    /// Live per-shard metric snapshots (what `/metrics` exports).
    pub fn metric_snapshots(&self) -> Vec<Snapshot> {
        match &self.inner {
            Some(i) => i
                .shards
                .lock()
                .unwrap()
                .iter()
                .map(|e| e.metrics.snapshot())
                .collect(),
            None => Vec::new(),
        }
    }

    /// The `/metrics` body: Prometheus text over live shard snapshots
    /// and the telemetry hub's calibration report.
    pub fn render_prometheus(&self) -> String {
        let Some(i) = &self.inner else {
            return String::new();
        };
        let snaps = self.metric_snapshots();
        let cal = i.telemetry.lock().unwrap().calibration();
        crate::telemetry::export::prometheus(&snaps, &cal)
    }

    /// The `/traces` body: JSON lines over stitched traces, snapshots,
    /// and calibration (empty traces when telemetry is disabled).
    pub fn render_traces(&self) -> String {
        let Some(i) = &self.inner else {
            return String::new();
        };
        let tel = Arc::clone(&i.telemetry.lock().unwrap());
        let snaps = self.metric_snapshots();
        crate::telemetry::export::json_lines(&tel.traces(), &snaps,
                                             &tel.calibration())
    }

    /// The `/events` body: one JSON object per breadcrumb, oldest first.
    pub fn render_events(&self) -> String {
        let mut out = String::new();
        for e in self.events() {
            out.push_str(&e.to_json());
            out.push('\n');
        }
        out
    }

    pub(crate) fn stopping(&self) -> bool {
        match &self.inner {
            Some(i) => i.stopping.load(Ordering::SeqCst),
            None => true,
        }
    }

    /// Stop the sampler and accept threads and join them. Records the
    /// shutdown breadcrumb. Idempotent; safe to call without `start`.
    pub fn stop(&self) {
        let Some(i) = &self.inner else { return };
        if i.stopped.swap(true, Ordering::SeqCst) {
            return;
        }
        i.recorder.lock().unwrap().push(Event {
            at_ms: i.epoch.elapsed().as_millis() as u64,
            shard: None,
            kind: EventKind::Shutdown,
            detail: "monitor stopped".to_string(),
        });
        i.stopping.store(true, Ordering::SeqCst);
        // unblock a blocking accept() with a throwaway connection
        if let Some(addr) = *i.bound.lock().unwrap() {
            let _ = std::net::TcpStream::connect_timeout(
                &addr,
                Duration::from_millis(200),
            );
        }
        let threads = std::mem::take(&mut *i.threads.lock().unwrap());
        for t in threads {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_config() -> MonitorConfig {
        MonitorConfig {
            interval: Duration::from_millis(20),
            history: 32,
            slo: None,
            pressure: true,
            events: 16,
        }
    }

    #[test]
    fn disabled_monitor_is_inert() {
        let m = Monitor::disabled();
        assert!(!m.enabled());
        let pulse = m.register_shard(0, Arc::new(Metrics::new_shard(0)));
        assert!(!pulse.enabled());
        m.sample_now();
        m.start();
        m.stop();
        assert!(m.health().is_none());
        assert!(m.events().is_empty());
        assert!(m.fleet_history().is_empty());
        assert_eq!(format!("{m:?}"), "Monitor(disabled)");
    }

    #[test]
    fn ticks_fill_rings_and_derive_shed_events() {
        let m = Monitor::new(quick_config());
        let sink = Arc::new(Metrics::new_shard(0));
        let pulse = m.register_shard(0, sink.clone());
        pulse.touch();
        m.sample_now();
        sink.record_query(100.0, 1.0, 1);
        sink.record_rejected();
        sink.record_rejected();
        pulse.touch();
        m.sample_now();
        assert_eq!(m.fleet_history().len(), 2);
        let (id, hist) = &m.shard_histories()[0];
        assert_eq!(*id, 0);
        assert_eq!(hist.len(), 2);
        assert_eq!(hist[1].snap.queries, 1);
        let sheds: Vec<Event> = m
            .events()
            .into_iter()
            .filter(|e| e.kind == EventKind::Shed)
            .collect();
        assert_eq!(sheds.len(), 1, "one shed burst breadcrumb");
        assert!(sheds[0].detail.contains("2 rejection(s)"), "{:?}", sheds[0]);
        m.stop();
    }

    #[test]
    fn watchdog_flags_a_silent_shard_on_demand() {
        let m = Monitor::new(quick_config());
        let live = m.register_shard(0, Arc::new(Metrics::new_shard(0)));
        let _dead = m.register_shard(1, Arc::new(Metrics::new_shard(1)));
        // shard 1 never beats after registration; one interval later the
        // on-demand health check must flag it without any sampler tick
        std::thread::sleep(Duration::from_millis(45));
        live.touch();
        let h = m.health().unwrap();
        assert!(!h.healthy);
        assert!(!h.shards[0].wedged, "beating shard is fine");
        assert!(h.shards[1].wedged, "silent shard flagged: {h:?}");
        assert!(h.to_json().contains("\"wedged\":true"));
        m.stop();
    }

    #[test]
    fn slo_breach_sets_the_pressure_flag_and_breadcrumbs() {
        let mut cfg = quick_config();
        cfg.slo = Some(SloParams {
            latency_us: 100_000.0,
            quantile: 0.95,
            availability: 0.9,
            fast_window_ms: 10_000,
            slow_window_ms: 20_000,
            burn_threshold: 2.0,
        });
        let m = Monitor::new(cfg);
        let sink = Arc::new(Metrics::new_shard(0));
        let pulse = m.register_shard(0, sink.clone());
        pulse.touch();
        m.sample_now();
        // every arrival rejected: failure fraction 1.0 / budget 0.1 = 10×
        for _ in 0..20 {
            sink.record_rejected();
        }
        pulse.touch();
        m.sample_now();
        let status = m.slo_status().unwrap();
        assert!(status.breached, "{status:?}");
        assert_eq!(pulse.pressure_boost(), SLO_PRESSURE_BOOST);
        assert!(m
            .events()
            .iter()
            .any(|e| e.kind == EventKind::SloBreach));
        let h = m.health().unwrap();
        assert!(!h.healthy);
        // recovery: lots of clean traffic drives the windows back down
        for _ in 0..2_000 {
            sink.record_query(50.0, 1.0, 1);
        }
        pulse.touch();
        m.sample_now();
        assert!(!m.slo_status().unwrap().breached);
        assert_eq!(pulse.pressure_boost(), 0);
        assert!(m
            .events()
            .iter()
            .any(|e| e.kind == EventKind::SloRecovered));
        m.stop();
    }

    #[test]
    fn panic_breadcrumb_lands_in_the_post_mortem() {
        let m = Monitor::new(quick_config());
        let pulse = m.register_shard(2, Arc::new(Metrics::new_shard(2)));
        m.start();
        pulse.panicked("mask buffer corrupted");
        m.stop();
        let report = m.post_mortem();
        assert!(report.contains("panicked: true"), "{report}");
        assert!(report.contains("shard_panic"), "{report}");
        assert!(report.contains("mask buffer corrupted"), "{report}");
        // launch ... panic ... shutdown, in order
        let launch = report.find("launch").unwrap();
        let panic_at = report.find("shard_panic").unwrap();
        let shutdown = report.find("shutdown").unwrap();
        assert!(launch < panic_at && panic_at < shutdown, "{report}");
        let h = m.health().unwrap();
        assert!(h.panicked && !h.healthy);
    }

    #[test]
    fn start_and_stop_are_idempotent() {
        let m = Monitor::new(quick_config());
        let _p = m.register_shard(0, Arc::new(Metrics::new_shard(0)));
        m.start();
        m.start();
        std::thread::sleep(Duration::from_millis(60));
        m.stop();
        m.stop();
        // the sampler thread ticked at least once before the join
        assert!(!m.fleet_history().is_empty());
        let kinds: Vec<EventKind> = m.events().iter().map(|e| e.kind).collect();
        assert_eq!(kinds.iter().filter(|k| **k == EventKind::Launch).count(), 1);
        assert_eq!(
            kinds.iter().filter(|k| **k == EventKind::Shutdown).count(),
            1
        );
    }
}
