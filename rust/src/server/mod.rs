//! Dynamic-graph serving: the single-leader front end that the paper's
//! motivating applications (on-device knowledge graphs, event-based
//! vision — Fig. 1/10) run on.
//!
//! Architecture: a single **leader thread** owns the inference engine
//! (PJRT executables are not `Send`; single ownership is also the right
//! consistency story for GrAd). Callers talk to it through an ordered
//! event channel: structure updates (GrAd) are applied in arrival order
//! with *no recompilation* — just mask invalidation — and queries are
//! coalesced by the batcher so one full-graph inference answers every
//! query in the window.
//!
//! Since the fleet landed, the leader loop *is* a fleet shard worker:
//! [`ServerHandle`] wraps a single [`crate::fleet::ShardWorker`] covering
//! the whole graph, with no halo exchange and unbounded admission. The
//! multi-shard generalization lives in [`crate::fleet`]; the shared event
//! types ([`Update`], [`QueryResponse`]) and the [`InferenceEngine`]
//! trait are defined here and used by both layers.
//!
//! Failure contract: a worker-thread panic (or engine-init failure)
//! rejects every in-flight query with an explicit error — counted in
//! [`crate::metrics::Metrics`]'s `rejected` — and [`ServerHandle::shutdown`]
//! returns an `Err` carrying the panic message. Callers are never left
//! hanging on a response channel, and crashes cannot hide behind a
//! swallowed join.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::fleet::shard::{ShardConfig, ShardWorker};
use crate::metrics::Metrics;
use crate::tensor::Mat;

/// What a serving worker executes. Implementations: the real PJRT-backed
/// [`CoordinatorEngine`], the artifact-free [`crate::fleet::LocalEngine`],
/// and in-process mocks for tests.
pub trait InferenceEngine {
    /// Apply a GrAd structure update. Returns the new graph version.
    fn apply(&mut self, update: &Update) -> Result<u64>;
    /// Run one full-graph inference; returns logits (nodes × classes).
    fn infer(&mut self) -> Result<Mat>;
    /// Active node count (for request validation).
    fn num_nodes(&self) -> usize;
    /// Partition-aware engines report their *live* halo-import count
    /// (distinct non-owned boundary nodes) so fleet halo accounting
    /// tracks GrAd churn. `None` (the default) makes the shard worker
    /// fall back to the plan-time static schedule.
    fn halo_imports(&self) -> Option<usize> {
        None
    }
    /// Delta-aware engines drain the accounting of their last inference
    /// round (recomputed rows, frontier size, cache hits) here; the
    /// shard worker records it after every round. `None` (the default)
    /// means the engine recomputes everything and has nothing to report.
    fn round_stats(&mut self) -> Option<crate::metrics::RoundStats> {
        None
    }
    /// Offer the engine the deployment's telemetry hub (called once by
    /// the shard worker, after construction). Plan-backed engines attach
    /// per-op profilers here; the default ignores it — engines without a
    /// compiled plan have nothing to profile.
    fn attach_telemetry(
        &mut self,
        _telemetry: &Arc<crate::telemetry::Telemetry>,
        _shard: usize,
    ) {
    }
    /// The shard worker reports its live query-queue depth here just
    /// before each inference round. Adaptive engines (the `auto`
    /// strategy switcher) fold it into their switching signals; the
    /// default ignores it — static engines have nothing to adapt.
    fn note_queue_depth(&mut self, _pending: usize) {}
}

/// Boxed engines pass through unchanged — this is what lets the
/// [`crate::serve::EngineRegistry`]'s factories (which produce
/// `Box<dyn InferenceEngine>`) feed the same generic
/// [`crate::fleet::Fleet::spawn`] / [`ServerHandle::spawn_with`] paths
/// as concrete engine types.
impl InferenceEngine for Box<dyn InferenceEngine> {
    fn apply(&mut self, update: &Update) -> Result<u64> {
        (**self).apply(update)
    }

    fn infer(&mut self) -> Result<Mat> {
        (**self).infer()
    }

    fn num_nodes(&self) -> usize {
        (**self).num_nodes()
    }

    fn halo_imports(&self) -> Option<usize> {
        (**self).halo_imports()
    }

    fn round_stats(&mut self) -> Option<crate::metrics::RoundStats> {
        (**self).round_stats()
    }

    fn attach_telemetry(
        &mut self,
        telemetry: &Arc<crate::telemetry::Telemetry>,
        shard: usize,
    ) {
        (**self).attach_telemetry(telemetry, shard)
    }

    fn note_queue_depth(&mut self, pending: usize) {
        (**self).note_queue_depth(pending)
    }
}

/// GrAd structure updates.
#[derive(Debug, Clone, PartialEq)]
pub enum Update {
    AddEdge(usize, usize),
    RemoveEdge(usize, usize),
    AddNode,
}

/// A query answer.
#[derive(Debug, Clone)]
pub struct QueryResponse {
    pub id: u64,
    /// Which shard answered (always 0 on the single-leader server).
    pub shard: usize,
    /// Predicted class of the queried node (or of node 0 for full-graph).
    pub prediction: i32,
    pub latency_us: f64,
    pub batch_size: usize,
}

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_batch: 16, max_wait: Duration::from_millis(2) }
    }
}

/// Client handle: submit updates/queries from any thread.
pub struct ServerHandle {
    shard: Option<ShardWorker>,
    pub metrics: Arc<Metrics>,
    telemetry: Arc<crate::telemetry::Telemetry>,
    monitor: crate::monitor::Monitor,
    next_id: AtomicU64,
}

impl ServerHandle {
    /// Spawn the leader thread. `factory` constructs the engine *inside*
    /// the thread (PJRT handles are not `Send`).
    pub fn spawn<F, E>(factory: F, config: ServerConfig) -> ServerHandle
    where
        F: FnOnce() -> Result<E> + Send + 'static,
        E: InferenceEngine,
    {
        ServerHandle::spawn_with(factory, ShardConfig::leader(config))
    }

    /// [`ServerHandle::spawn`] with the full shard config — how
    /// [`crate::serve::Deployment::launch`] gives the 1-shard topology
    /// the same admission policy a fleet shard would get (halo is
    /// meaningless on a single leader and stays `None` either way).
    pub fn spawn_with<F, E>(factory: F, config: ShardConfig) -> ServerHandle
    where
        F: FnOnce() -> Result<E> + Send + 'static,
        E: InferenceEngine,
    {
        let telemetry = Arc::clone(&config.telemetry);
        let monitor = config.monitor.clone();
        let shard = ShardWorker::spawn(0, factory, config);
        ServerHandle {
            metrics: shard.metrics.clone(),
            shard: Some(shard),
            telemetry,
            monitor,
            next_id: AtomicU64::new(1),
        }
    }

    fn shard(&self) -> &ShardWorker {
        self.shard.as_ref().expect("server already shut down")
    }

    /// Apply a structure update (GrAd): ordered before any later query.
    pub fn update(&self, u: Update) -> Result<()> {
        self.shard().update(u).map_err(|_| anyhow!("server stopped"))
    }

    /// Submit a query; returns a receiver for the response.
    pub fn query(&self, node: Option<usize>)
                 -> Result<Receiver<Result<QueryResponse, String>>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.shard()
            .query_with_id(id, node)
            .map_err(|_| anyhow!("server stopped"))
    }

    /// Stop the leader and join it. A worker panic surfaces here as an
    /// `Err` carrying the panic message (in-flight queries were already
    /// answered with rejections and counted).
    pub fn shutdown(mut self) -> Result<()> {
        let result = match self.shard.take() {
            Some(s) => s.shutdown(),
            None => Ok(()),
        };
        if result.is_err() && self.monitor.enabled() {
            // the worker died abnormally: dump the flight recorder so
            // the breadcrumbs survive the process
            eprintln!("{}", self.monitor.post_mortem());
        }
        self.monitor.stop();
        result
    }
}

/// The single-leader server is the 1-shard [`crate::serve::Serving`]
/// topology: blocking waits ([`crate::serve::Serving::query_wait`],
/// [`crate::serve::Serving::query_deadline`]) come from the trait's
/// provided methods.
impl crate::serve::Serving for ServerHandle {
    fn update(&self, u: Update) -> Result<()> {
        ServerHandle::update(self, u)
    }

    fn query(&self, node: Option<usize>)
             -> Result<Receiver<Result<QueryResponse, String>>> {
        ServerHandle::query(self, node)
    }

    fn sync(&self) -> Result<Vec<u64>> {
        Ok(vec![self.shard().sync()?])
    }

    fn metrics(&self) -> crate::metrics::Snapshot {
        self.metrics.snapshot()
    }

    fn shard_metrics(&self) -> Vec<crate::metrics::Snapshot> {
        vec![self.metrics.snapshot()]
    }

    fn num_shards(&self) -> usize {
        1
    }

    fn telemetry(&self) -> Option<Arc<crate::telemetry::Telemetry>> {
        Some(Arc::clone(&self.telemetry))
    }

    fn monitor(&self) -> Option<crate::monitor::Monitor> {
        if self.monitor.enabled() {
            Some(self.monitor.clone())
        } else {
            None
        }
    }

    fn record_shed(&self, _node: Option<usize>) {
        self.metrics.record_rejected();
    }

    fn shutdown(self: Box<Self>) -> Result<()> {
        ServerHandle::shutdown(*self)
    }
}

/// The production engine: a [`crate::coordinator::Coordinator`] bound to
/// one artifact (typically a `*_grad_*` NodePad-compiled blob).
pub struct CoordinatorEngine {
    pub coordinator: crate::coordinator::Coordinator,
    pub artifact: String,
}

impl InferenceEngine for CoordinatorEngine {
    fn apply(&mut self, update: &Update) -> Result<u64> {
        let st = &mut self.coordinator.state;
        match update {
            Update::AddEdge(u, v) => {
                st.add_edge(*u, *v)?;
            }
            Update::RemoveEdge(u, v) => {
                st.remove_edge(*u, *v)?;
            }
            Update::AddNode => {
                st.add_node()?;
            }
        }
        Ok(st.graph_version())
    }

    fn infer(&mut self) -> Result<Mat> {
        self.coordinator.infer(&self.artifact)
    }

    fn num_nodes(&self) -> usize {
        self.coordinator.state.num_active_nodes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ModelState;
    use crate::graph::datasets::synthesize;
    use crate::serve::Serving;

    /// Mock engine: logits = one-hot of (node id + version) % classes so
    /// tests can verify update ordering effects deterministically.
    struct MockEngine {
        state: ModelState,
        infer_calls: usize,
    }

    impl MockEngine {
        fn new() -> MockEngine {
            let ds = synthesize("mock", 20, 40, 4, 8, 5);
            MockEngine {
                state: ModelState::from_dataset(ds, 30).unwrap(),
                infer_calls: 0,
            }
        }
    }

    impl InferenceEngine for MockEngine {
        fn apply(&mut self, update: &Update) -> Result<u64> {
            match update {
                Update::AddEdge(u, v) => {
                    self.state.add_edge(*u, *v)?;
                }
                Update::RemoveEdge(u, v) => {
                    self.state.remove_edge(*u, *v)?;
                }
                Update::AddNode => {
                    self.state.add_node()?;
                }
            }
            Ok(self.state.graph_version())
        }

        fn infer(&mut self) -> Result<Mat> {
            self.infer_calls += 1;
            let n = self.state.num_active_nodes();
            let v = self.state.graph_version() as usize;
            let mut m = Mat::zeros(n, 4);
            for i in 0..n {
                m[(i, (i + v) % 4)] = 1.0;
            }
            Ok(m)
        }

        fn num_nodes(&self) -> usize {
            self.state.num_active_nodes()
        }
    }

    fn spawn_mock() -> ServerHandle {
        ServerHandle::spawn(
            || Ok(MockEngine::new()),
            ServerConfig { max_batch: 8, max_wait: Duration::from_millis(2) },
        )
    }

    #[test]
    fn serves_queries() {
        let s = spawn_mock();
        let r = s.query_wait(Some(3)).unwrap();
        assert_eq!(r.prediction, 3); // version 0: (3 + 0) % 4
        assert_eq!(r.shard, 0);
        s.shutdown().unwrap();
    }

    #[test]
    fn updates_order_before_later_queries() {
        let s = spawn_mock();
        // bump version with a guaranteed-fresh update, then query
        s.update(Update::AddNode).unwrap();
        let r = s.query_wait(Some(3)).unwrap();
        assert_eq!(r.prediction, 0); // (3 + 1) % 4
        s.shutdown().unwrap();
    }

    #[test]
    fn out_of_range_query_rejected() {
        let s = spawn_mock();
        let err = s.query_wait(Some(999)).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        assert_eq!(s.metrics.snapshot().rejected, 1);
        s.shutdown().unwrap();
    }

    #[test]
    fn batches_coalesce_concurrent_queries() {
        let s = Arc::new(spawn_mock());
        let handles: Vec<_> = (0..12)
            .map(|i| {
                let s = s.clone();
                std::thread::spawn(move || s.query_wait(Some(i % 10)).unwrap())
            })
            .collect();
        let responses: Vec<QueryResponse> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(responses.len(), 12);
        // at least some coalescing happened
        let max_batch = responses.iter().map(|r| r.batch_size).max().unwrap();
        assert!(max_batch >= 2, "no batching observed");
        let snap = s.metrics.snapshot();
        assert_eq!(snap.queries, 12);
    }

    #[test]
    fn capacity_exhaustion_counts_rejections() {
        let s = spawn_mock();
        for _ in 0..10 {
            s.update(Update::AddNode).unwrap(); // capacity 30, start 20
        }
        s.update(Update::AddNode).unwrap(); // 31st → rejected inside
        // force processing before snapshot
        let _ = s.query_wait(None).unwrap();
        assert!(s.metrics.snapshot().rejected >= 1);
        s.shutdown().unwrap();
    }

    #[test]
    fn metrics_track_mask_updates() {
        let s = spawn_mock();
        s.update(Update::AddEdge(1, 2)).unwrap();
        s.update(Update::RemoveEdge(1, 2)).unwrap();
        let _ = s.query_wait(None).unwrap();
        assert_eq!(s.metrics.snapshot().mask_updates, 2);
        s.shutdown().unwrap();
    }

    #[test]
    fn worker_panic_rejects_in_flight_and_errors_shutdown() {
        struct PanicOnInfer;
        impl InferenceEngine for PanicOnInfer {
            fn apply(&mut self, _: &Update) -> Result<u64> {
                Ok(0)
            }
            fn infer(&mut self) -> Result<Mat> {
                panic!("simulated engine crash");
            }
            fn num_nodes(&self) -> usize {
                16
            }
        }
        let s = ServerHandle::spawn(|| Ok(PanicOnInfer), ServerConfig::default());
        let rx = s.query(Some(1)).unwrap();
        // in-flight query gets an explicit rejection, not a dropped channel
        let err = rx.recv().expect("responder must not be dropped").unwrap_err();
        assert!(err.contains("panicked"), "{err}");
        assert!(s.metrics.snapshot().rejected >= 1);
        // ...and the panic surfaces from shutdown with its message
        let shut = s.shutdown().unwrap_err().to_string();
        assert!(shut.contains("simulated engine crash"), "{shut}");
    }
}
