//! Dynamic-graph serving: the leader/worker runtime that the paper's
//! motivating applications (on-device knowledge graphs, event-based
//! vision — Fig. 1/10) run on.
//!
//! Architecture: a single **leader thread** owns the inference engine
//! (PJRT executables are not `Send`; single ownership is also the right
//! consistency story for GrAd). Callers talk to it through an ordered
//! event channel: structure updates (GrAd) are applied in arrival order
//! with *no recompilation* — just mask invalidation — and queries are
//! coalesced by the [`Batcher`] so one full-graph inference answers every
//! query in the window.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::batcher::{Batcher, Request};
use crate::metrics::Metrics;
use crate::tensor::Mat;

/// What the leader thread executes. Implementations: the real
/// PJRT-backed [`crate::coordinator::Coordinator`] (see
/// [`coordinator_engine`]) and in-process mocks for tests.
pub trait InferenceEngine {
    /// Apply a GrAd structure update. Returns the new graph version.
    fn apply(&mut self, update: &Update) -> Result<u64>;
    /// Run one full-graph inference; returns logits (nodes × classes).
    fn infer(&mut self) -> Result<Mat>;
    /// Active node count (for request validation).
    fn num_nodes(&self) -> usize;
}

/// GrAd structure updates.
#[derive(Debug, Clone, PartialEq)]
pub enum Update {
    AddEdge(usize, usize),
    RemoveEdge(usize, usize),
    AddNode,
}

/// A query answer.
#[derive(Debug, Clone)]
pub struct QueryResponse {
    pub id: u64,
    /// Predicted class of the queried node (or of node 0 for full-graph).
    pub prediction: i32,
    pub latency_us: f64,
    pub batch_size: usize,
}

enum Event {
    Update(Update),
    Query { req: Request, resp: Sender<Result<QueryResponse, String>> },
    Shutdown,
}

/// Client handle: submit updates/queries from any thread.
pub struct ServerHandle {
    tx: Sender<Event>,
    pub metrics: Arc<Metrics>,
    join: Option<JoinHandle<Result<()>>>,
    next_id: std::sync::atomic::AtomicU64,
}

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_batch: 16, max_wait: Duration::from_millis(2) }
    }
}

impl ServerHandle {
    /// Spawn the leader thread. `factory` constructs the engine *inside*
    /// the thread (PJRT handles are not `Send`).
    pub fn spawn<F, E>(factory: F, config: ServerConfig) -> ServerHandle
    where
        F: FnOnce() -> Result<E> + Send + 'static,
        E: InferenceEngine,
    {
        let (tx, rx) = channel::<Event>();
        let metrics = Arc::new(Metrics::new());
        let m = metrics.clone();
        let join = std::thread::spawn(move || leader_loop(factory, rx, m, config));
        ServerHandle {
            tx,
            metrics,
            join: Some(join),
            next_id: std::sync::atomic::AtomicU64::new(1),
        }
    }

    /// Apply a structure update (GrAd): ordered before any later query.
    pub fn update(&self, u: Update) -> Result<()> {
        self.tx
            .send(Event::Update(u))
            .map_err(|_| anyhow!("server stopped"))
    }

    /// Submit a query; returns a receiver for the response.
    pub fn query(&self, node: Option<usize>) -> Result<Receiver<Result<QueryResponse, String>>> {
        let (resp_tx, resp_rx) = channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.tx
            .send(Event::Query {
                req: Request { id, node, enqueued: Instant::now() },
                resp: resp_tx,
            })
            .map_err(|_| anyhow!("server stopped"))?;
        Ok(resp_rx)
    }

    /// Blocking convenience: query and wait.
    pub fn query_wait(&self, node: Option<usize>) -> Result<QueryResponse> {
        let rx = self.query(node)?;
        rx.recv()
            .map_err(|_| anyhow!("server dropped response"))?
            .map_err(|e| anyhow!(e))
    }

    /// Stop the leader and join it.
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Event::Shutdown);
        if let Some(j) = self.join.take() {
            j.join().map_err(|_| anyhow!("leader panicked"))??;
        }
        Ok(())
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Event::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn leader_loop<F, E>(factory: F, rx: Receiver<Event>, metrics: Arc<Metrics>,
                     config: ServerConfig) -> Result<()>
where
    F: FnOnce() -> Result<E>,
    E: InferenceEngine,
{
    let mut engine = factory()?;
    let batcher = Batcher::new(config.max_batch, config.max_wait);
    let mut waiting: std::collections::BTreeMap<u64, Sender<Result<QueryResponse, String>>> =
        Default::default();
    let mut version = 0u64;
    let mut open = true;

    while open || batcher.pending() > 0 {
        // ingest events for up to the batching window
        match rx.recv_timeout(config.max_wait.min(Duration::from_millis(1))) {
            Ok(Event::Update(u)) => match engine.apply(&u) {
                Ok(v) => {
                    version = v;
                    batcher.note_update(v);
                    metrics.record_mask_update();
                }
                Err(e) => {
                    // capacity exhaustion etc: drop the update, count it
                    metrics.record_rejected();
                    let _ = e;
                }
            },
            Ok(Event::Query { req, resp }) => {
                if let Some(n) = req.node {
                    if n >= engine.num_nodes() {
                        metrics.record_rejected();
                        let _ = resp.send(Err(format!(
                            "node {n} out of range ({} active)",
                            engine.num_nodes()
                        )));
                        continue;
                    }
                }
                waiting.insert(req.id, resp);
                batcher.submit(req);
            }
            Ok(Event::Shutdown) | Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                open = false;
                batcher.close();
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
        }

        // flush a batch if ready
        if let Some(batch) = batcher.try_batch() {
            let t0 = Instant::now();
            let result = engine.infer();
            let latency_us = t0.elapsed().as_secs_f64() * 1e6;
            let size = batch.requests.len();
            match result {
                Ok(logits) => {
                    let preds = logits.argmax_rows();
                    for req in batch.requests {
                        let node = req.node.unwrap_or(0);
                        let queue_us =
                            req.enqueued.elapsed().as_secs_f64() * 1e6 - latency_us;
                        metrics.record_query(latency_us, queue_us.max(0.0), size);
                        if let Some(resp) = waiting.remove(&req.id) {
                            let _ = resp.send(Ok(QueryResponse {
                                id: req.id,
                                prediction: preds.get(node).map(|&p| p as i32).unwrap_or(-1),
                                latency_us,
                                batch_size: size,
                            }));
                        }
                    }
                }
                Err(e) => {
                    let msg = format!("inference failed: {e:#}");
                    for req in batch.requests {
                        metrics.record_rejected();
                        if let Some(resp) = waiting.remove(&req.id) {
                            let _ = resp.send(Err(msg.clone()));
                        }
                    }
                }
            }
            let _ = version;
        }
    }
    Ok(())
}

/// The production engine: a [`crate::coordinator::Coordinator`] bound to
/// one artifact (typically a `*_grad_*` NodePad-compiled blob).
pub struct CoordinatorEngine {
    pub coordinator: crate::coordinator::Coordinator,
    pub artifact: String,
}

impl InferenceEngine for CoordinatorEngine {
    fn apply(&mut self, update: &Update) -> Result<u64> {
        let st = &mut self.coordinator.state;
        match update {
            Update::AddEdge(u, v) => {
                st.add_edge(*u, *v)?;
            }
            Update::RemoveEdge(u, v) => {
                st.remove_edge(*u, *v)?;
            }
            Update::AddNode => {
                st.add_node()?;
            }
        }
        Ok(st.graph_version())
    }

    fn infer(&mut self) -> Result<Mat> {
        self.coordinator.infer(&self.artifact)
    }

    fn num_nodes(&self) -> usize {
        self.coordinator.state.num_active_nodes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ModelState;
    use crate::graph::datasets::synthesize;

    /// Mock engine: logits = one-hot of (node id + version) % classes so
    /// tests can verify update ordering effects deterministically.
    struct MockEngine {
        state: ModelState,
        infer_calls: usize,
    }

    impl MockEngine {
        fn new() -> MockEngine {
            let ds = synthesize("mock", 20, 40, 4, 8, 5);
            MockEngine {
                state: ModelState::from_dataset(ds, 30).unwrap(),
                infer_calls: 0,
            }
        }
    }

    impl InferenceEngine for MockEngine {
        fn apply(&mut self, update: &Update) -> Result<u64> {
            match update {
                Update::AddEdge(u, v) => {
                    self.state.add_edge(*u, *v)?;
                }
                Update::RemoveEdge(u, v) => {
                    self.state.remove_edge(*u, *v)?;
                }
                Update::AddNode => {
                    self.state.add_node()?;
                }
            }
            Ok(self.state.graph_version())
        }

        fn infer(&mut self) -> Result<Mat> {
            self.infer_calls += 1;
            let n = self.state.num_active_nodes();
            let v = self.state.graph_version() as usize;
            let mut m = Mat::zeros(n, 4);
            for i in 0..n {
                m[(i, (i + v) % 4)] = 1.0;
            }
            Ok(m)
        }

        fn num_nodes(&self) -> usize {
            self.state.num_active_nodes()
        }
    }

    fn spawn_mock() -> ServerHandle {
        ServerHandle::spawn(
            || Ok(MockEngine::new()),
            ServerConfig { max_batch: 8, max_wait: Duration::from_millis(2) },
        )
    }

    #[test]
    fn serves_queries() {
        let s = spawn_mock();
        let r = s.query_wait(Some(3)).unwrap();
        assert_eq!(r.prediction, 3); // version 0: (3 + 0) % 4
        s.shutdown().unwrap();
    }

    #[test]
    fn updates_order_before_later_queries() {
        let s = spawn_mock();
        // bump version with a guaranteed-fresh update, then query
        s.update(Update::AddNode).unwrap();
        let r = s.query_wait(Some(3)).unwrap();
        assert_eq!(r.prediction, 0); // (3 + 1) % 4
        s.shutdown().unwrap();
    }

    #[test]
    fn out_of_range_query_rejected() {
        let s = spawn_mock();
        let err = s.query_wait(Some(999)).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
        assert_eq!(s.metrics.snapshot().rejected, 1);
        s.shutdown().unwrap();
    }

    #[test]
    fn batches_coalesce_concurrent_queries() {
        let s = Arc::new(spawn_mock());
        let handles: Vec<_> = (0..12)
            .map(|i| {
                let s = s.clone();
                std::thread::spawn(move || s.query_wait(Some(i % 10)).unwrap())
            })
            .collect();
        let responses: Vec<QueryResponse> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(responses.len(), 12);
        // at least some coalescing happened
        let max_batch = responses.iter().map(|r| r.batch_size).max().unwrap();
        assert!(max_batch >= 2, "no batching observed");
        let snap = s.metrics.snapshot();
        assert_eq!(snap.queries, 12);
    }

    #[test]
    fn capacity_exhaustion_counts_rejections() {
        let s = spawn_mock();
        for _ in 0..10 {
            s.update(Update::AddNode).unwrap(); // capacity 30, start 20
        }
        s.update(Update::AddNode).unwrap(); // 31st → rejected inside
        // force processing before snapshot
        let _ = s.query_wait(None).unwrap();
        assert!(s.metrics.snapshot().rejected >= 1);
        s.shutdown().unwrap();
    }

    #[test]
    fn metrics_track_mask_updates() {
        let s = spawn_mock();
        s.update(Update::AddEdge(1, 2)).unwrap();
        s.update(Update::RemoveEdge(1, 2)).unwrap();
        let _ = s.query_wait(None).unwrap();
        assert_eq!(s.metrics.snapshot().mask_updates, 2);
        s.shutdown().unwrap();
    }
}
