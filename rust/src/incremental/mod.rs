//! `incremental` — delta-driven inference: recompute the dirty frontier,
//! serve the rest from a layer-activation cache.
//!
//! The serving stack recomputes **every node through every layer** per
//! query, even when the graph changed by one edge since the last answer
//! — yet GrAd/NodePad keep the compiled shapes stable precisely so work
//! *could* be reused, and a k-layer GNN output can only change inside
//! the k-hop ball of a mutation (aggregation locality). This subsystem
//! exploits that:
//!
//! - [`frontier`] accumulates mutation seeds from GrAd updates and
//!   expands them k hops over the live neighbor sets (`B(seeds, l)` is
//!   the exact layer-l dirty superset — see the module's soundness
//!   argument);
//! - [`cache`] holds per-layer node activations in an arena-backed,
//!   epoch-versioned store (CacheG generalized from adjacency masks to
//!   activations) with precise per-row invalidation;
//! - [`IncrementalEngine`] implements the serving
//!   [`crate::server::InferenceEngine`] trait: per round it recomputes
//!   layer `l` only for `B(seeds, l+1)` (∩ the shard's region), reading
//!   ring inputs from the cache and scattering fresh rows back, through
//!   the gather/scatter tile path ([`crate::engine::TileRunner`] running
//!   compiled [`crate::ops::plan::ExecPlan`]s at power-of-two-bucketed
//!   subset shapes).
//!
//! ## Fallback cost model
//!
//! Per round the engine estimates both paths in flops-plus-gather terms
//! (at the *bucketed* tile sizes it would actually run) and takes the
//! full recompute when
//! `est(incremental) ≥ cost_margin · est(full)` — small-churn wins must
//! not become large-churn regressions, so beyond the crossover the
//! engine *is* the full planned path plus an O(frontier) bookkeeping
//! term. With no pending mutations a round recomputes nothing at all and
//! answers straight from the cache.
//!
//! ## Fleet sharding
//!
//! A shard owning `O` maintains layer `l` for the region `B(O, k−1−l)`
//! (its halo ring, one hop wider per earlier layer). Updates fan out to
//! every shard, so a boundary mutation lands in the neighbor shard's
//! frontier and invalidates/recomputes its cached rows automatically;
//! live halo imports are recosted per round from the actual input rings
//! (`|rings ∖ owned|`), shrinking with the frontier.

pub mod cache;
pub mod frontier;

use std::cell::RefCell;
use std::collections::BTreeSet;
use std::ops::Range;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::coordinator::ModelState;
use crate::engine::{kernels, TileRunner, WorkerPool};
use crate::graph::datasets::Dataset;
use crate::metrics::RoundStats;
use crate::ops::build::{self, Aggregation};
use crate::ops::exec::Bindings;
use crate::server::{InferenceEngine, Update};
use crate::storage::{FeatureSource, MemoryFeatures, StorageStats};
use crate::tensor::Mat;

pub use cache::ActivationCache;
pub use frontier::Frontier;

/// Tuning knobs for the delta-driven engine.
#[derive(Debug, Clone, Copy)]
pub struct IncrementalConfig {
    /// Take the full path when `est_inc ≥ cost_margin · est_full`. `0.0`
    /// forces full recompute every round; `f64::INFINITY` disables the
    /// fallback (test/bench hooks for both sides of the crossover).
    pub cost_margin: f64,
    /// Smallest tile bucket (avoids compiling a plan per tiny frontier).
    pub tile_min: usize,
    /// Where tile gathers read the norm mask from: `Sparse` indexes the
    /// CSR rows straight through `indptr` (never materializing the
    /// capacity² dense mask), `Dense` reads the incrementally-maintained
    /// dense matrix, `Auto` resolves per round from the live density.
    pub aggregation: Aggregation,
    /// Kernel dispatch knobs compiled into every tile plan (SIMD
    /// microkernels, degree-binned scheduling) — frontier tiles route
    /// through the same vectorized paths as the full planned engines.
    pub kernels: crate::ops::plan::KernelConfig,
}

impl Default for IncrementalConfig {
    fn default() -> Self {
        // margin < 1: near the crossover the frontier bookkeeping and
        // scattered gathers make the full path the safer choice
        IncrementalConfig {
            cost_margin: 0.75,
            tile_min: 32,
            aggregation: Aggregation::Auto,
            kernels: crate::ops::plan::KernelConfig::default(),
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct LayerSpec {
    in_w: usize,
    out_w: usize,
    relu: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum RoundMode {
    /// No pending mutations: serve entirely from the cache.
    Cached,
    /// Recompute the whole owned region (cold cache or past the
    /// fallback threshold).
    Full,
    /// Recompute only the dirty frontier.
    Incremental,
}

/// One round's execution plan (what [`IncrementalEngine::infer`] runs
/// and what the halo/metrics accounting is derived from).
struct LayerRound {
    /// Rows to recompute at this layer (sorted).
    rows: Vec<usize>,
    /// Input ring `B(rows, 1)` (sorted; read from the previous layer's
    /// cache, or from the padded features at layer 0).
    ring: Vec<usize>,
    /// Dirty rows this engine is *not* recomputing (outside its region);
    /// precisely invalidated so they can never serve a stale read.
    stale: Vec<usize>,
}

struct RoundPlan {
    mode: RoundMode,
    layers: Vec<LayerRound>,
    /// Distinct non-owned nodes in this round's input rings — the live
    /// halo-import count.
    halo: usize,
    /// `|B(seeds, k)|` that drove the mode decision (0 when cached).
    frontier: usize,
}

/// Delta-driven [`InferenceEngine`]: frontier recompute over a
/// layer-activation cache, with cost-model fallback to full recompute.
/// See the module docs.
pub struct IncrementalEngine {
    state: ModelState,
    /// Where layer-0 ring gathers read node features from: RAM (the
    /// NodePad-padded `x_pad` matrix the full plans bind) or the paged
    /// on-disk store — the engine cannot tell which (`[storage]` spec
    /// section decides).
    features: Box<dyn FeatureSource>,
    layers: Vec<LayerSpec>,
    /// One tile family per layer (geometry-bucketed compiled plans).
    tiles: Vec<TileRunner>,
    cache: ActivationCache,
    frontier: RefCell<Frontier>,
    cfg: IncrementalConfig,
    owned: Range<usize>,
    /// True once a full round has seeded every region row of the cache.
    seeded: bool,
    /// Completed inference rounds (part of the plan-cache key).
    rounds: u64,
    plan_cache: RefCell<Option<(u64, u64, Arc<RoundPlan>)>>,
    /// Shard maintenance regions, cached per graph version.
    regions: RefCell<Option<(u64, Arc<Regions>)>>,
    last_stats: Option<RoundStats>,
    /// Mask-gather traffic of the last executed round:
    /// (dense-equivalent bytes, bytes actually shipped).
    last_dma: (usize, usize),
}

/// The per-version shard geometry: `per_layer[l] = B(owned, k−1−l)` and
/// the layer-0 input ring of a full recompute, `ring0 = B(per_layer[0], 1)`
/// — precomputed so the cost model prices the full path at the ring it
/// actually gathers.
struct Regions {
    per_layer: Vec<Vec<u32>>,
    ring0: Vec<u32>,
}

impl IncrementalEngine {
    /// Core constructor: an existing [`ModelState`] (GrAd graph + CacheG
    /// masks) plus a named weight set (`w1`/`b1`/`w2`/`b2`, …) — real
    /// artifact weights or the deterministic offline synthesis. Answers
    /// for `owned` only (the single-leader server owns everything).
    pub fn from_state(
        state: ModelState,
        weights: Bindings,
        owned: Range<usize>,
        pool: Arc<WorkerPool>,
        cfg: IncrementalConfig,
    ) -> Result<IncrementalEngine> {
        let features = Box::new(MemoryFeatures::padded(&state.dataset.features, state.capacity));
        IncrementalEngine::from_state_with_source(state, weights, owned, pool, cfg, features)
    }

    /// [`IncrementalEngine::from_state`] with an explicit feature
    /// backend — the out-of-core entry point: hand it a
    /// [`crate::storage::PagedFeatures`] and layer-0 ring gathers read
    /// from the page cache instead of a resident `x_pad` matrix. The
    /// source must cover the NodePad capacity at the model's feature
    /// width.
    pub fn from_state_with_source(
        state: ModelState,
        weights: Bindings,
        owned: Range<usize>,
        pool: Arc<WorkerPool>,
        cfg: IncrementalConfig,
        features: Box<dyn FeatureSource>,
    ) -> Result<IncrementalEngine> {
        let mut layers: Vec<LayerSpec> = Vec::new();
        loop {
            let Some(w) = weights.get(&format!("w{}", layers.len() + 1)) else {
                break;
            };
            let shape = w.shape();
            if shape.len() != 2 {
                bail!("weight w{} is not 2-D", layers.len() + 1);
            }
            layers.push(LayerSpec { in_w: shape[0], out_w: shape[1], relu: true });
        }
        if layers.is_empty() {
            bail!("no w1/w2/… weights to build an incremental model from");
        }
        let k = layers.len();
        layers[k - 1].relu = false;
        if layers[0].in_w != state.dataset.num_features() {
            bail!(
                "w1 expects {} features, dataset has {}",
                layers[0].in_w,
                state.dataset.num_features()
            );
        }
        let capacity = state.capacity;
        if features.width() != layers[0].in_w {
            bail!(
                "feature source is {} wide, model w1 expects {}",
                features.width(),
                layers[0].in_w
            );
        }
        if features.rows() < capacity {
            bail!(
                "feature source holds {} rows, NodePad capacity is {capacity}",
                features.rows()
            );
        }
        let cache =
            ActivationCache::new(capacity, &layers.iter().map(|l| l.out_w).collect::<Vec<_>>());
        let mut tiles = Vec::with_capacity(k);
        for (li, spec) in layers.iter().enumerate() {
            let mut statics = Bindings::new();
            let wkey = format!("w{}", li + 1);
            let bkey = format!("b{}", li + 1);
            let w = weights.get(&wkey).unwrap().clone();
            let b = weights
                .get(&bkey)
                .with_context(|| format!("missing bias {bkey}"))?
                .clone();
            if b.num_elements() != spec.out_w {
                bail!("{bkey} has {} elements, layer wants {}", b.num_elements(), spec.out_w);
            }
            statics.insert("w".into(), w);
            statics.insert("b".into(), b);
            let (in_w, out_w, relu) = (spec.in_w, spec.out_w, spec.relu);
            let mut runner = TileRunner::new(
                Arc::clone(&pool),
                cfg.tile_min,
                capacity,
                capacity,
                statics,
                move |rows, ring| build::gcn_layer_tile(rows, ring, in_w, out_w, relu),
            );
            runner.set_kernels(cfg.kernels);
            tiles.push(runner);
        }
        Ok(IncrementalEngine {
            frontier: RefCell::new(Frontier::new(capacity)),
            state,
            features,
            layers,
            tiles,
            cache,
            cfg,
            owned,
            seeded: false,
            rounds: 0,
            plan_cache: RefCell::new(None),
            regions: RefCell::new(None),
            last_stats: None,
            last_dma: (0, 0),
        })
    }

    /// Offline shard engine: deterministic synthesized weights (the same
    /// ones [`crate::fleet::PlanEngine`] serves, so fleets of either
    /// engine agree), answering for `owned` only.
    pub fn shard(
        ds: &Dataset,
        capacity: usize,
        owned: Range<usize>,
        pool: Arc<WorkerPool>,
        cfg: IncrementalConfig,
    ) -> Result<IncrementalEngine> {
        let capacity = capacity.max(ds.num_nodes());
        let weights = crate::fleet::engine::synthesize_weights(
            ds.num_features(),
            ds.num_classes().max(2),
            capacity,
        );
        let state = ModelState::from_dataset(ds.clone(), capacity)?;
        IncrementalEngine::from_state(state, weights, owned, pool, cfg)
    }

    /// [`IncrementalEngine::shard`] with an explicit feature backend
    /// (the `[storage] backend = "paged"` lowering). The dataset's
    /// feature matrix may be empty (0 rows at the model width): with an
    /// on-disk source nothing forces features to ever be resident.
    pub fn shard_with_source(
        ds: &Dataset,
        capacity: usize,
        owned: Range<usize>,
        pool: Arc<WorkerPool>,
        cfg: IncrementalConfig,
        features: Box<dyn FeatureSource>,
    ) -> Result<IncrementalEngine> {
        let capacity = capacity.max(ds.num_nodes());
        let weights = crate::fleet::engine::synthesize_weights(
            ds.num_features(),
            ds.num_classes().max(2),
            capacity,
        );
        let state = ModelState::from_dataset(ds.clone(), capacity)?;
        IncrementalEngine::from_state_with_source(state, weights, owned, pool, cfg, features)
    }

    /// Overwrite one node's input features (GrAd feature churn). Writes
    /// through the storage tier — on the paged backend this dirties
    /// exactly one page, which is precisely invalidated — and seeds the
    /// node so the next round recomputes its k-hop ball.
    pub fn write_features(&mut self, node: usize, values: &[f32]) -> Result<()> {
        if node >= self.active() {
            bail!("write_features: node {node} is not active ({} live)", self.active());
        }
        self.features.write_row(node, values)?;
        // a feature change dirties exactly B({node}, l) at layer l —
        // the same seed geometry as a self-loop edge mutation
        self.frontier.get_mut().note(&Update::AddEdge(node, node), None);
        // the cached round layout assumed clean features
        *self.plan_cache.get_mut() = None;
        Ok(())
    }

    /// Materialize the feature matrix the engine is serving from
    /// (oracle/debug path — allocates; gathers through the backend).
    pub fn features_dense(&mut self) -> Result<Mat> {
        self.features.to_mat()
    }

    /// Offline engine answering for every node (the single-leader
    /// server).
    pub fn full(
        ds: &Dataset,
        capacity: usize,
        pool: Arc<WorkerPool>,
        cfg: IncrementalConfig,
    ) -> Result<IncrementalEngine> {
        let capacity = capacity.max(ds.num_nodes());
        IncrementalEngine::shard(ds, capacity, 0..capacity, pool, cfg)
    }

    /// The last completed round's accounting (also drained through
    /// [`InferenceEngine::round_stats`] by shard workers).
    pub fn last_round(&self) -> Option<&RoundStats> {
        self.last_stats.as_ref()
    }

    /// Tile plans compiled so far, across layers (compile-once gauge).
    pub fn compiled_tiles(&self) -> usize {
        self.tiles.iter().map(TileRunner::compiled_tiles).sum()
    }

    fn num_layers(&self) -> usize {
        self.layers.len()
    }

    fn active(&self) -> usize {
        self.state.num_active_nodes()
    }

    fn owned_active(&self) -> Range<usize> {
        let n = self.active();
        self.owned.start.min(n)..self.owned.end.min(n)
    }

    fn owns_all(&self) -> bool {
        self.owned.start == 0 && self.owned.end >= self.state.capacity
    }

    /// Per-layer maintenance regions `B(owned ∩ active, k−1−l)` plus the
    /// layer-0 full-recompute ring, cached per graph version.
    fn region_sets(&self) -> Arc<Regions> {
        let version = self.state.graph_version();
        if let Some((v, r)) = &*self.regions.borrow() {
            if *v == version {
                return Arc::clone(r);
            }
        }
        let k = self.num_layers();
        let out = if self.owns_all() {
            let all: Vec<u32> = (0..self.active() as u32).collect();
            Arc::new(Regions { per_layer: vec![all.clone(); k], ring0: all })
        } else {
            let owned: Vec<u32> =
                self.owned_active().map(|i| i as u32).collect();
            let mut f = self.frontier.borrow_mut();
            let per_layer: Vec<Vec<u32>> = (0..k)
                .map(|l| {
                    f.ball_of(&owned, k - 1 - l, |u, visit| {
                        for &v in self.state.neighbors(u) {
                            visit(v);
                        }
                    })
                })
                .collect();
            let ring0 = f.ball_of(&per_layer[0], 1, |u, visit| {
                for &v in self.state.neighbors(u) {
                    visit(v);
                }
            });
            Arc::new(Regions { per_layer, ring0 })
        };
        *self.regions.borrow_mut() = Some((version, Arc::clone(&out)));
        out
    }

    /// Estimated cost (flops + gather traffic) of executing the given
    /// per-layer `(rows, ring)` sizes at their *bucketed* tile shapes.
    fn est_cost(&self, sizes: &[(usize, usize)]) -> f64 {
        let mut total = 0.0;
        for (l, &(rows, ring)) in sizes.iter().enumerate() {
            if rows == 0 {
                continue;
            }
            let spec = &self.layers[l];
            let (rb, qb) = self.tiles[l].bucket(rows, ring);
            let (rb, qb, in_w, out_w) =
                (rb as f64, qb as f64, spec.in_w as f64, spec.out_w as f64);
            // combination mm + aggregation mm + input gather + mask gather
            total += qb * in_w * out_w + rb * qb * out_w + qb * in_w + rb * qb;
        }
        total
    }

    /// Decide and lay out the next round. Cached per
    /// `(graph version, completed rounds)` so the halo probe and the
    /// inference that follows it share one expansion.
    fn plan_round(&self) -> Arc<RoundPlan> {
        let key = (self.state.graph_version(), self.rounds);
        if let Some((v, r, p)) = &*self.plan_cache.borrow() {
            if (*v, *r) == key {
                return Arc::clone(p);
            }
        }
        let plan = Arc::new(self.build_plan());
        *self.plan_cache.borrow_mut() = Some((key.0, key.1, Arc::clone(&plan)));
        plan
    }

    fn build_plan(&self) -> RoundPlan {
        let k = self.num_layers();
        if self.seeded && self.frontier.borrow().is_clean() {
            return RoundPlan {
                mode: RoundMode::Cached,
                layers: Vec::new(),
                halo: 0,
                frontier: 0,
            };
        }
        let regions = self.region_sets();

        // dirty balls — meaningful only once the cache is seeded (a cold
        // cache has nothing to preserve, so there is nothing to expand)
        let balls = if self.seeded {
            let mut f = self.frontier.borrow_mut();
            Some(f.balls(k, |u, visit| {
                for &v in self.state.neighbors(u) {
                    visit(v);
                }
            }))
        } else {
            None
        };
        let frontier_size = balls.as_ref().map(|b| b[k].len()).unwrap_or(0);

        if let Some(balls) = &balls {
            // candidate incremental layout, then the cost-model decision
            let mut layers = Vec::with_capacity(k);
            {
                let mut f = self.frontier.borrow_mut();
                for l in 0..k {
                    let dirty = intersect_sorted(&balls[l + 1], &regions.per_layer[l]);
                    // churn can *grow* a shard's region (a new edge pulls
                    // nodes into B(owned, j)); any region row whose cached
                    // value is invalid must be recomputed alongside the
                    // frontier, or a later ring read would hit it stale
                    let unseeded: Vec<u32> = regions.per_layer[l]
                        .iter()
                        .copied()
                        .filter(|&r| !self.cache.is_valid(l, r as usize))
                        .collect();
                    let rows = union_sorted(&dirty, &unseeded);
                    let ring = f.ball_of(&rows, 1, |u, visit| {
                        for &v in self.state.neighbors(u) {
                            visit(v);
                        }
                    });
                    let stale = difference_sorted(&balls[l + 1], &rows);
                    layers.push(LayerRound {
                        rows: to_usize(&rows),
                        ring: to_usize(&ring),
                        stale: to_usize(&stale),
                    });
                }
            }
            let inc_sizes: Vec<(usize, usize)> =
                layers.iter().map(|l| (l.rows.len(), l.ring.len())).collect();
            // price the full path at the rings it actually gathers:
            // layer 0 reads B(region[0], 1), layer l ≥ 1 reads region[l−1]
            let full_sizes: Vec<(usize, usize)> = (0..k)
                .map(|l| {
                    let ring = if l == 0 {
                        regions.ring0.len()
                    } else {
                        regions.per_layer[l - 1].len()
                    };
                    (regions.per_layer[l].len(), ring)
                })
                .collect();
            if self.est_cost(&inc_sizes)
                < self.cfg.cost_margin * self.est_cost(&full_sizes)
            {
                let halo = self.halo_of(&layers);
                return RoundPlan {
                    mode: RoundMode::Incremental,
                    layers,
                    halo,
                    frontier: frontier_size,
                };
            }
        }

        // full recompute over the maintenance regions. Dirty rows outside
        // the regions still have to be precisely invalidated: a node that
        // later re-enters a region must not serve a stale-but-valid row.
        let mut layers = Vec::with_capacity(k);
        for l in 0..k {
            let rows = to_usize(&regions.per_layer[l]);
            let ring = if l == 0 {
                to_usize(&regions.ring0)
            } else {
                to_usize(&regions.per_layer[l - 1])
            };
            let stale = balls
                .as_ref()
                .map(|b| {
                    to_usize(&difference_sorted(&b[l + 1], &regions.per_layer[l]))
                })
                .unwrap_or_default();
            layers.push(LayerRound { rows, ring, stale });
        }
        let halo = self.halo_of(&layers);
        RoundPlan { mode: RoundMode::Full, layers, halo, frontier: frontier_size }
    }

    /// Distinct non-owned nodes across the round's input rings.
    fn halo_of(&self, layers: &[LayerRound]) -> usize {
        if self.owns_all() {
            return 0;
        }
        let mut imports: BTreeSet<usize> = BTreeSet::new();
        for lr in layers {
            for &n in &lr.ring {
                if !self.owned.contains(&n) {
                    imports.insert(n);
                }
            }
        }
        imports.len()
    }

    /// The (resolved) norm-gather mode for the current graph state.
    fn gather_mode(&self) -> Aggregation {
        let cap = self.state.capacity as f64;
        let density = (2.0 * self.state.num_edges() as f64
            + self.active() as f64)
            / (cap * cap);
        self.cfg.aggregation.resolve(density)
    }

    /// Execute one planned round through the gather/scatter tile path.
    fn exec_round(&mut self, plan: &RoundPlan) -> Result<()> {
        let capacity = self.state.capacity;
        let sparse = self.gather_mode().lowers_sparse();
        self.last_dma = (0, 0);
        // the layer-0 ring (frontier + halo imports) is known before any
        // tile runs: hand it to the storage tier so a paged backend can
        // read the pages while the norm gather and tile binding proceed
        if let Some(l0) = plan.layers.first() {
            self.features.stage(&l0.ring);
        }
        for l in 0..self.num_layers() {
            let lr = &plan.layers[l];
            if !lr.stale.is_empty() {
                self.cache.invalidate_rows(l, &lr.stale);
            }
            if lr.rows.is_empty() {
                continue;
            }
            let spec = self.layers[l];
            let tile = self.tiles[l].tile(lr.rows.len(), lr.ring.len())?;
            let ring_cap = tile.ring;
            let hbuf = tile.binding_mut("h_ring")?;
            if l == 0 {
                self.features
                    .gather(&lr.ring, &mut hbuf[..lr.ring.len() * spec.in_w])
                    .context("layer-0 feature gather")?;
            } else {
                let stale = self.cache.gather(l - 1, &lr.ring, hbuf);
                if stale > 0 {
                    bail!(
                        "incremental invariant broken: {stale} stale ring rows \
                         at layer {l} (frontier under-expansion)"
                    );
                }
            }
            // norm tile gather: CSR row slices (frontier rows index
            // straight into indptr, O(nnz(rows)·log|ring|)) or the dense
            // submatrix copy — both produce the identical padded tile
            let dense_bytes = lr.rows.len() * lr.ring.len() * 4;
            let shipped = if sparse {
                let nbuf = tile.binding_mut("norm_sub")?;
                let csr = self.state.norm_csr();
                let written = kernels::gather_csr_submatrix(
                    &csr.indptr,
                    &csr.indices,
                    &csr.values,
                    &lr.rows,
                    &lr.ring,
                    nbuf,
                    ring_cap,
                );
                // indptr slice + (index, value) per stored entry
                lr.rows.len() * 4 + written * 8
            } else {
                kernels::gather_submatrix(
                    &self.state.norm_mask().data,
                    capacity,
                    &lr.rows,
                    &lr.ring,
                    tile.binding_mut("norm_sub")?,
                    ring_cap,
                );
                dense_bytes
            };
            self.last_dma.0 += dense_bytes;
            self.last_dma.1 += shipped.min(dense_bytes);
            tile.run()
                .with_context(|| format!("incremental layer {l} tile run"))?;
            let (out, _rows, out_w) = tile.output()?;
            debug_assert_eq!(out_w, spec.out_w);
            // scatter the fresh rows back into the cache (copy the live
            // region out of the tile view to split the field borrows)
            let fresh = out[..lr.rows.len() * out_w].to_vec();
            self.cache.scatter(l, &lr.rows, &fresh);
        }
        Ok(())
    }

    fn round_accounting(&self, plan: &RoundPlan, storage: StorageStats) -> RoundStats {
        let eligible = self.owned_active().len();
        let (dma_bytes_dense, dma_bytes_shipped) = self.last_dma;
        match plan.mode {
            RoundMode::Cached => RoundStats {
                recomputed_rows: 0,
                eligible_rows: eligible,
                frontier: 0,
                cache_hits: eligible,
                cache_misses: 0,
                dma_bytes_dense,
                dma_bytes_shipped,
                page_hits: storage.hits,
                page_faults: storage.faults,
                storage_bytes_read: storage.bytes_read,
                ..Default::default()
            },
            RoundMode::Full | RoundMode::Incremental => {
                let k = self.num_layers();
                let recomputed = plan.layers[k - 1].rows.len();
                let mut misses = 0usize;
                let mut hits = eligible.saturating_sub(recomputed);
                for l in 0..k {
                    misses += plan.layers[l].rows.len();
                    if l > 0 {
                        hits += count_not_in(
                            &plan.layers[l].ring,
                            &plan.layers[l - 1].rows,
                        );
                    }
                }
                RoundStats {
                    recomputed_rows: recomputed,
                    eligible_rows: eligible,
                    frontier: plan.frontier,
                    cache_hits: hits,
                    cache_misses: misses,
                    dma_bytes_dense,
                    dma_bytes_shipped,
                    page_hits: storage.hits,
                    page_faults: storage.faults,
                    storage_bytes_read: storage.bytes_read,
                    ..Default::default()
                }
            }
        }
    }
}

fn to_usize(v: &[u32]) -> Vec<usize> {
    v.iter().map(|&x| x as usize).collect()
}

/// `a ∩ b` for sorted slices.
fn intersect_sorted(a: &[u32], b: &[u32]) -> Vec<u32> {
    let mut out = Vec::new();
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                out.push(a[i]);
                i += 1;
                j += 1;
            }
        }
    }
    out
}

/// `a ∪ b` for sorted slices.
fn union_sorted(a: &[u32], b: &[u32]) -> Vec<u32> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() || j < b.len() {
        let x = match (a.get(i), b.get(j)) {
            (Some(&x), Some(&y)) if x == y => {
                i += 1;
                j += 1;
                x
            }
            (Some(&x), Some(&y)) if x < y => {
                i += 1;
                x
            }
            (Some(_), Some(&y)) => {
                j += 1;
                y
            }
            (Some(&x), None) => {
                i += 1;
                x
            }
            (None, Some(&y)) => {
                j += 1;
                y
            }
            (None, None) => unreachable!(),
        };
        out.push(x);
    }
    out
}

/// `a ∖ b` for sorted slices.
fn difference_sorted(a: &[u32], b: &[u32]) -> Vec<u32> {
    let mut out = Vec::new();
    let mut j = 0;
    for &x in a {
        while j < b.len() && b[j] < x {
            j += 1;
        }
        if j >= b.len() || b[j] != x {
            out.push(x);
        }
    }
    out
}

/// Entries of sorted `a` not present in sorted `b`.
fn count_not_in(a: &[usize], b: &[usize]) -> usize {
    let mut j = 0;
    let mut count = 0;
    for &x in a {
        while j < b.len() && b[j] < x {
            j += 1;
        }
        if j >= b.len() || b[j] != x {
            count += 1;
        }
    }
    count
}

impl InferenceEngine for IncrementalEngine {
    fn apply(&mut self, update: &Update) -> Result<u64> {
        match update {
            Update::AddEdge(u, v) => {
                if self.state.add_edge(*u, *v)? {
                    self.frontier.get_mut().note(update, None);
                }
            }
            Update::RemoveEdge(u, v) => {
                if self.state.remove_edge(*u, *v)? {
                    self.frontier.get_mut().note(update, None);
                }
            }
            Update::AddNode => {
                let id = self.state.add_node()?;
                // the activated row must never serve a stale cached
                // feature page (pre-built stores may carry non-zero
                // padding rows that a cache warmed before activation)
                self.features.invalidate_rows(&[id]);
                self.frontier.get_mut().note(update, Some(id));
            }
        }
        Ok(self.state.graph_version())
    }

    fn infer(&mut self) -> Result<Mat> {
        let plan = self.plan_round();
        if plan.mode == RoundMode::Cached {
            self.last_dma = (0, 0); // nothing gathered, nothing shipped
        }
        if plan.mode != RoundMode::Cached {
            if let Err(e) = self.exec_round(&plan) {
                // a half-written round must never serve: stale everything
                // and drop the planned layout (it assumed a live cache)
                self.cache.invalidate_all();
                self.seeded = false;
                self.frontier.get_mut().clear();
                *self.plan_cache.get_mut() = None;
                return Err(e);
            }
            self.frontier.get_mut().clear();
            if plan.mode == RoundMode::Full {
                self.seeded = true;
            }
        }
        let storage = self.features.take_stats();
        self.last_stats = Some(self.round_accounting(&plan, storage));
        self.rounds += 1;

        // serve from the cache: active rows, zeros outside this shard's
        // validity region (same contract as the other shard engines)
        let n = self.active();
        let k = self.num_layers();
        let classes = self.layers[k - 1].out_w;
        let mut out = Mat::zeros(n, classes);
        for i in 0..n {
            if let Some(row) = self.cache.row(k - 1, i) {
                out.row_mut(i).copy_from_slice(row);
            }
        }
        Ok(out)
    }

    fn num_nodes(&self) -> usize {
        self.active()
    }

    /// Live halo imports, recosted from the upcoming round's input rings
    /// — O(frontier) under churn, 0 for cache-served rounds.
    fn halo_imports(&self) -> Option<usize> {
        Some(self.plan_round().halo)
    }

    fn round_stats(&mut self) -> Option<RoundStats> {
        self.last_stats.take()
    }

    /// Attach per-step plan profiling to every layer's tile runner —
    /// tiles compiled later (new frontier buckets) pick it up lazily.
    /// No-op for a disabled hub.
    fn attach_telemetry(
        &mut self,
        telemetry: &std::sync::Arc<crate::telemetry::Telemetry>,
        shard: usize,
    ) {
        for tiles in &mut self.tiles {
            tiles.set_telemetry(std::sync::Arc::clone(telemetry), shard);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::synthesize;
    use crate::ops::build::GnnDims;
    use crate::ops::exec;

    fn ds() -> Dataset {
        synthesize("inc", 40, 60, 4, 12, 29)
    }

    fn serial() -> Arc<WorkerPool> {
        Arc::new(WorkerPool::serial())
    }

    /// Force the incremental path (tests of the frontier execution
    /// itself, not of the cost model's crossover point).
    fn never_fall_back() -> IncrementalConfig {
        IncrementalConfig {
            cost_margin: f64::INFINITY,
            tile_min: 8,
            ..Default::default()
        }
    }

    /// Reference logits via the full-graph oracle at the engine's exact
    /// bindings (same synthesized weights, snapshot-rebuilt norm).
    fn oracle(eng: &mut IncrementalEngine) -> Mat {
        let x = eng.features_dense().unwrap();
        let cap = eng.state.capacity;
        let ds = &eng.state.dataset;
        let classes = eng.layers.last().unwrap().out_w;
        let dims = GnnDims::model(cap, ds.graph.num_edges(), ds.num_features(), classes);
        let g = crate::ops::build::gcn_stagr(dims, "grad");
        let mut b = crate::fleet::engine::synthesize_weights(
            ds.num_features(),
            classes,
            cap,
        );
        b.insert(
            "norm".into(),
            crate::tensor::Tensor::from_mat(
                &eng.state.snapshot_graph().norm_adjacency(cap),
            ),
        );
        b.insert("x".into(), crate::tensor::Tensor::from_mat(&x));
        let full = exec::execute_mat(&g, &b).unwrap();
        let n = eng.active();
        Mat::from_fn(n, full.cols, |i, j| full[(i, j)])
    }

    #[test]
    fn first_round_is_full_then_cached() {
        let ds = ds();
        let mut eng = IncrementalEngine::full(&ds, 48, serial(),
                                              IncrementalConfig::default()).unwrap();
        let a = eng.infer().unwrap();
        let rs = eng.round_stats().unwrap();
        assert_eq!(rs.recomputed_rows, 40, "cold cache → full recompute");
        assert_eq!(rs.cache_hits, 0);
        let b = eng.infer().unwrap();
        let rs = eng.round_stats().unwrap();
        assert_eq!(rs.recomputed_rows, 0, "no churn → pure cache serve");
        assert_eq!(rs.cache_hits, 40);
        assert_eq!(a, b, "cached round must reproduce the full round");
        let want = oracle(&mut eng);
        assert!(want.max_abs_diff(&a) < 1e-4, "drift {}", want.max_abs_diff(&a));
    }

    #[test]
    fn single_edge_churn_recomputes_a_small_frontier() {
        // sparse 80-node graph: a 2-hop ball around one edge cannot come
        // near covering it
        let ds = synthesize("inc-sparse", 80, 60, 4, 12, 29);
        let mut eng =
            IncrementalEngine::full(&ds, 88, serial(), never_fall_back()).unwrap();
        let _ = eng.infer().unwrap();
        let _ = eng.round_stats();
        // remove-then-add guarantees seeds whether or not the edge existed
        eng.apply(&Update::RemoveEdge(0, 40)).unwrap();
        eng.apply(&Update::AddEdge(0, 40)).unwrap();
        let got = eng.infer().unwrap();
        let rs = eng.round_stats().unwrap();
        assert!(rs.recomputed_rows < 40, "frontier must not cover the graph");
        assert!(rs.recomputed_rows > 0);
        assert!(rs.frontier > 0 && rs.frontier < 40);
        assert!(rs.cache_hits > 0, "untouched rows must serve from cache");
        let want = oracle(&mut eng);
        assert!(want.max_abs_diff(&got) < 1e-4, "drift {}", want.max_abs_diff(&got));
    }

    #[test]
    fn add_node_activates_and_answers() {
        let ds = ds();
        let mut eng =
            IncrementalEngine::full(&ds, 48, serial(), never_fall_back()).unwrap();
        let _ = eng.infer().unwrap();
        eng.apply(&Update::AddNode).unwrap();
        eng.apply(&Update::AddEdge(40, 3)).unwrap();
        let got = eng.infer().unwrap();
        assert_eq!(got.rows, 41);
        let want = oracle(&mut eng);
        assert!(want.max_abs_diff(&got) < 1e-4);
    }

    #[test]
    fn margin_zero_always_takes_the_full_path() {
        let ds = ds();
        let mut eng = IncrementalEngine::full(
            &ds, 48, serial(),
            IncrementalConfig { cost_margin: 0.0, ..Default::default() },
        ).unwrap();
        let _ = eng.infer().unwrap();
        let _ = eng.round_stats();
        eng.apply(&Update::RemoveEdge(1, 30)).unwrap();
        eng.apply(&Update::AddEdge(1, 30)).unwrap();
        let got = eng.infer().unwrap();
        let rs = eng.round_stats().unwrap();
        assert_eq!(rs.recomputed_rows, 40, "margin 0 must force full recompute");
        let want = oracle(&mut eng);
        assert!(want.max_abs_diff(&got) < 1e-4);
    }

    #[test]
    fn duplicate_updates_do_not_dirty_the_cache() {
        let ds = ds();
        let mut eng = IncrementalEngine::full(&ds, 48, serial(),
                                              IncrementalConfig::default()).unwrap();
        let _ = eng.infer().unwrap();
        // an edge that certainly exists after we add it once
        eng.apply(&Update::AddEdge(2, 17)).unwrap();
        let _ = eng.infer().unwrap();
        let _ = eng.round_stats();
        eng.apply(&Update::AddEdge(2, 17)).unwrap(); // duplicate
        let _ = eng.infer().unwrap();
        let rs = eng.round_stats().unwrap();
        assert_eq!(rs.recomputed_rows, 0, "no-op update must stay cache-served");
    }

    #[test]
    fn shard_engine_computes_owned_rows_and_reports_halo() {
        let ds = ds();
        let mut full =
            IncrementalEngine::full(&ds, 48, serial(), never_fall_back()).unwrap();
        let mut shard =
            IncrementalEngine::shard(&ds, 48, 0..15, serial(), never_fall_back())
                .unwrap();
        // cold cache: the upcoming full round imports the boundary ring
        assert!(shard.halo_imports().unwrap() > 0, "cold shard must import halo");
        assert_eq!(full.halo_imports(), Some(0), "sole owner imports nothing");
        let a = full.infer().unwrap();
        let b = shard.infer().unwrap();
        for i in 0..15 {
            for j in 0..a.cols {
                assert_eq!(a[(i, j)], b[(i, j)], "owned row {i} diverged");
            }
        }
        // cache-served rounds ship nothing over the link
        assert_eq!(shard.halo_imports(), Some(0));
        // churn at the boundary: the shard must track the full engine,
        // and the halo recost follows the live frontier (remove-then-add
        // guarantees seeds on both engines whatever the synthetic graph)
        for u in [14usize, 15, 16] {
            for upd in [Update::RemoveEdge(u, u + 4), Update::AddEdge(u, u + 4)] {
                full.apply(&upd).unwrap();
                shard.apply(&upd).unwrap();
            }
        }
        assert!(shard.halo_imports().unwrap() > 0, "boundary churn needs halo");
        let a = full.infer().unwrap();
        let b = shard.infer().unwrap();
        for i in 0..15 {
            for j in 0..a.cols {
                let d = (a[(i, j)] - b[(i, j)]).abs();
                assert!(d < 1e-5, "post-churn owned row {i} drift {d}");
            }
        }
    }

    #[test]
    fn sparse_and_dense_gathers_agree_and_sparse_skips_the_dense_mask() {
        let ds = synthesize("inc-agg", 60, 90, 4, 12, 31);
        let mk = |agg: Aggregation| {
            IncrementalEngine::full(
                &ds,
                64,
                serial(),
                IncrementalConfig { aggregation: agg, ..never_fall_back() },
            )
            .unwrap()
        };
        let mut sparse = mk(Aggregation::Sparse);
        let mut dense = mk(Aggregation::Dense);
        // auto resolves sparse at this density ((180+60)/64² ≈ 0.06)
        assert!(mk(Aggregation::Auto).gather_mode().lowers_sparse());
        let churn: Vec<Update> = (0..8)
            .flat_map(|i| {
                [Update::RemoveEdge(i, i + 13), Update::AddEdge(i, i + 13)]
            })
            .collect();
        let a = sparse.infer().unwrap();
        let b = dense.infer().unwrap();
        assert_eq!(a, b, "cold full rounds must agree");
        for u in &churn {
            sparse.apply(u).unwrap();
            dense.apply(u).unwrap();
        }
        let a = sparse.infer().unwrap();
        let b = dense.infer().unwrap();
        assert_eq!(a, b, "post-churn frontier rounds must agree");
        // the sparse engine never materialized the capacity² dense mask
        assert!(!sparse.state.dense_norm_materialized());
        assert!(dense.state.dense_norm_materialized());
        // dma gauge: sparse ships (far) fewer bytes than dense-equivalent
        let rs = sparse.round_stats().unwrap();
        assert!(rs.dma_bytes_dense > 0);
        assert!(
            rs.dma_bytes_shipped < rs.dma_bytes_dense,
            "{} !< {}",
            rs.dma_bytes_shipped,
            rs.dma_bytes_dense
        );
        let rd = dense.round_stats().unwrap();
        assert_eq!(rd.dma_bytes_shipped, rd.dma_bytes_dense, "dense ships dense");
        // oracle agreement after churn
        let want = oracle(&mut sparse);
        assert!(want.max_abs_diff(&a) < 1e-4, "drift {}", want.max_abs_diff(&a));
    }

    #[test]
    fn scalar_kernel_tiles_match_default_bitwise() {
        // tiles route through the same microkernel dispatch as the
        // planned engines: the scalar-oracle configuration must agree
        // exactly with the SIMD default, cold rounds and frontier rounds
        use crate::ops::plan::{KernelConfig, SimdMode};
        let ds = ds();
        let mk = |simd: SimdMode| {
            IncrementalEngine::full(
                &ds,
                48,
                serial(),
                IncrementalConfig {
                    kernels: KernelConfig { simd, ..KernelConfig::default() },
                    ..never_fall_back()
                },
            )
            .unwrap()
        };
        let mut simd = mk(SimdMode::Auto);
        let mut scalar = mk(SimdMode::Off);
        assert_eq!(simd.infer().unwrap(), scalar.infer().unwrap());
        for eng in [&mut simd, &mut scalar] {
            eng.apply(&Update::RemoveEdge(0, 21)).unwrap();
            eng.apply(&Update::AddEdge(0, 21)).unwrap();
        }
        assert_eq!(simd.infer().unwrap(), scalar.infer().unwrap());
    }

    #[test]
    fn compile_once_tiles_are_reused_across_rounds() {
        let ds = ds();
        let mut eng =
            IncrementalEngine::full(&ds, 48, serial(), never_fall_back()).unwrap();
        let _ = eng.infer().unwrap();
        let after_full = eng.compiled_tiles();
        for i in 0..6 {
            eng.apply(&Update::AddEdge(i, i + 9)).unwrap();
            let _ = eng.infer().unwrap();
        }
        assert!(eng.compiled_tiles() >= after_full);
        for i in 0..6 {
            eng.apply(&Update::RemoveEdge(i, i + 9)).unwrap();
            let _ = eng.infer().unwrap();
        }
        // 2 layers × a handful of pow2 buckets — NOT a tile per frontier
        assert!(
            eng.compiled_tiles() <= 10,
            "{} tiles for 13 rounds: buckets are not being reused",
            eng.compiled_tiles()
        );
    }
}
