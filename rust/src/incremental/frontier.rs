//! Dirty-frontier tracking: which nodes can a batch of GrAd mutations
//! have changed, k layers deep?
//!
//! ## Soundness
//!
//! A k-layer GNN output row depends only on the node's k-hop
//! neighborhood (the aggregation locality EnGN and the Abadal et al.
//! survey exploit for tiling). An edge mutation `(u,v)` rescales norm
//! entries in rows/columns `u` and `v` only, so the layer-1 dirty set is
//! `{u,v} ∪ N(u) ∪ N(v) = B({u,v}, 1)` and, inductively, the layer-l
//! dirty set is `B(seeds, l)` — the l-hop ball around the mutation
//! endpoints.
//!
//! Expansion runs over the **current** graph even when several mutations
//! accumulated between queries. That is still a superset of the true
//! dirty set: any neighbor a node *lost* since the last query is itself
//! a seed (removing `(u,x)` seeds `x`), so `N_old(u) ⊆ N_now(u) ∪ seeds`
//! and the inductive argument goes through unchanged. The brute-force
//! before/after diffing test in `rust/tests/incremental_equivalence.rs`
//! checks exactly this containment.
//!
//! ## SAGE sampling
//!
//! Expansion takes the neighbor relation as a closure, so a
//! sampling-aware caller can pass its sampled adjacency. A node only
//! aggregates from its *sampled* neighbors — a subset of the full
//! neighbor set — so expanding over the full adjacency (what
//! [`Frontier::balls`] does by default) is a sound superset for SAGE
//! models too; passing the sampled relation merely tightens the
//! frontier.

use std::collections::BTreeSet;

use crate::server::Update;

/// Accumulates mutation seeds between queries and expands them into
/// layered k-hop balls with a reusable, generation-stamped scratch (no
/// per-expansion clearing of the visited array).
#[derive(Debug)]
pub struct Frontier {
    seeds: BTreeSet<u32>,
    /// `stamp[i] == gen` ⇔ node i visited in the current expansion.
    stamp: Vec<u32>,
    gen: u32,
}

impl Frontier {
    pub fn new(capacity: usize) -> Frontier {
        Frontier { seeds: BTreeSet::new(), stamp: vec![0; capacity], gen: 0 }
    }

    /// Note an **applied** update's seeds. Call only for updates that
    /// changed the graph (duplicate adds / absent removes touch nothing
    /// and must not grow the frontier); `added_node` is the id returned
    /// by a successful `AddNode`.
    pub fn note(&mut self, update: &Update, added_node: Option<usize>) {
        match update {
            Update::AddEdge(u, v) | Update::RemoveEdge(u, v) => {
                self.seeds.insert(*u as u32);
                self.seeds.insert(*v as u32);
            }
            Update::AddNode => {
                if let Some(id) = added_node {
                    self.seeds.insert(id as u32);
                }
            }
        }
    }

    pub fn num_seeds(&self) -> usize {
        self.seeds.len()
    }

    pub fn is_clean(&self) -> bool {
        self.seeds.is_empty()
    }

    /// Forget the accumulated seeds (after a successful recompute).
    pub fn clear(&mut self) {
        self.seeds.clear();
    }

    fn next_gen(&mut self) -> u32 {
        self.gen = self.gen.wrapping_add(1);
        if self.gen == 0 {
            // stamp wrap: every stale stamp could collide; reset once
            self.stamp.fill(0);
            self.gen = 1;
        }
        self.gen
    }

    /// Layered balls around the seeds: `out[l]` is the **sorted** set of
    /// nodes within `l` hops of any seed (`out[0]` = the seeds), for
    /// `l = 0..=k`. `nbrs(node, visit)` enumerates a node's neighbors.
    pub fn balls<N>(&mut self, k: usize, mut nbrs: N) -> Vec<Vec<u32>>
    where
        N: FnMut(usize, &mut dyn FnMut(u32)),
    {
        let gen = self.next_gen();
        let mut ball: Vec<u32> = self.seeds.iter().copied().collect();
        for &s in &ball {
            self.stamp[s as usize] = gen;
        }
        let mut out = Vec::with_capacity(k + 1);
        out.push(ball.clone());
        let mut wave = ball.clone();
        for _ in 0..k {
            let mut next = Vec::new();
            for &u in &wave {
                let stamp = &mut self.stamp;
                nbrs(u as usize, &mut |v: u32| {
                    if stamp[v as usize] != gen {
                        stamp[v as usize] = gen;
                        next.push(v);
                    }
                });
            }
            ball.extend_from_slice(&next);
            ball.sort_unstable();
            out.push(ball.clone());
            wave = next;
            if wave.is_empty() {
                // converged early: remaining balls repeat the last one
                while out.len() < k + 1 {
                    out.push(ball.clone());
                }
                break;
            }
        }
        out
    }

    /// `B(rows, hops)` for an arbitrary sorted row set — the input-ring
    /// computation (`hops = 1`) and the shard region expansion
    /// (`hops = k − l`). Returns a sorted superset of `rows`.
    pub fn ball_of<N>(&mut self, rows: &[u32], hops: usize, mut nbrs: N) -> Vec<u32>
    where
        N: FnMut(usize, &mut dyn FnMut(u32)),
    {
        let gen = self.next_gen();
        let mut ball: Vec<u32> = rows.to_vec();
        for &r in rows {
            self.stamp[r as usize] = gen;
        }
        let mut wave: Vec<u32> = rows.to_vec();
        for _ in 0..hops {
            let mut next = Vec::new();
            for &u in &wave {
                let stamp = &mut self.stamp;
                nbrs(u as usize, &mut |v: u32| {
                    if stamp[v as usize] != gen {
                        stamp[v as usize] = gen;
                        next.push(v);
                    }
                });
            }
            ball.extend_from_slice(&next);
            wave = next;
            if wave.is_empty() {
                break;
            }
        }
        ball.sort_unstable();
        ball
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    fn nbrs_of(g: &Graph) -> impl FnMut(usize, &mut dyn FnMut(u32)) {
        let lists = g.neighbor_lists();
        move |u: usize, visit: &mut dyn FnMut(u32)| {
            for &v in &lists[u] {
                visit(v);
            }
        }
    }

    /// Brute-force ball via repeated neighbor unions.
    fn brute_ball(g: &Graph, seeds: &[u32], k: usize) -> Vec<u32> {
        let lists = g.neighbor_lists();
        let mut set: BTreeSet<u32> = seeds.iter().copied().collect();
        for _ in 0..k {
            let cur: Vec<u32> = set.iter().copied().collect();
            for u in cur {
                for &v in &lists[u as usize] {
                    set.insert(v);
                }
            }
        }
        set.into_iter().collect()
    }

    #[test]
    fn balls_match_brute_force() {
        crate::util::propcheck::forall("frontier balls == brute force", 30, |gen| {
            let n = gen.usize(3, 30);
            let m = gen.usize(1, 3 * n);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| (gen.rng().usize(n) as u32, gen.rng().usize(n) as u32))
                .collect();
            let g = Graph::new(n, &edges);
            let mut f = Frontier::new(n);
            let nseeds = gen.usize(1, 4.min(n));
            for _ in 0..nseeds {
                let u = gen.rng().usize(n);
                f.note(&Update::AddEdge(u, u), None); // seeds both = u
            }
            let seeds: Vec<u32> = f.seeds.iter().copied().collect();
            let k = gen.usize(1, 4);
            let balls = f.balls(k, nbrs_of(&g));
            assert_eq!(balls.len(), k + 1);
            assert_eq!(balls[0], seeds);
            for (l, ball) in balls.iter().enumerate() {
                assert_eq!(ball, &brute_ball(&g, &seeds, l), "hop {l}");
            }
            // rings agree with brute force too
            let ring = f.ball_of(&balls[k], 1, nbrs_of(&g));
            assert_eq!(ring, brute_ball(&g, &balls[k], 1));
        });
    }

    #[test]
    fn note_ignores_unapplied_add_node() {
        let mut f = Frontier::new(8);
        f.note(&Update::AddNode, None);
        assert!(f.is_clean());
        f.note(&Update::AddNode, Some(5));
        assert_eq!(f.num_seeds(), 1);
        f.clear();
        assert!(f.is_clean());
    }

    #[test]
    fn scratch_survives_many_generations() {
        // the generation stamps must never leak state across expansions
        let g = Graph::new(6, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5)]);
        for round in 0..300 {
            let mut f = Frontier::new(6);
            f.note(&Update::AddEdge(round % 5, (round % 5) + 1), None);
            let seeds: Vec<u32> = f.seeds.iter().copied().collect();
            let balls = f.balls(2, nbrs_of(&g));
            assert_eq!(balls[2], brute_ball(&g, &seeds, 2));
        }
        // and the same instance reused back to back
        let mut f = Frontier::new(6);
        f.note(&Update::AddEdge(0, 1), None);
        let a = f.balls(1, nbrs_of(&g));
        let b = f.balls(1, nbrs_of(&g));
        assert_eq!(a, b);
    }

    #[test]
    fn sampled_relation_tightens_the_frontier() {
        // a star: full expansion from the hub reaches everyone; a
        // SAGE-style sampled relation that keeps 2 neighbors reaches 2
        let g = Graph::new(6, &[(0, 1), (0, 2), (0, 3), (0, 4), (0, 5)]);
        let mut f = Frontier::new(6);
        f.note(&Update::AddEdge(0, 1), None);
        let full = f.balls(1, nbrs_of(&g));
        assert_eq!(full[1].len(), 6);
        let sampled = f.balls(1, |u, visit: &mut dyn FnMut(u32)| {
            let lists = g.neighbor_lists();
            for &v in lists[u].iter().take(2) {
                visit(v);
            }
        });
        assert!(sampled[1].len() < full[1].len());
        // and it is a subset: sound, just tighter
        for v in &sampled[1] {
            assert!(full[1].contains(v));
        }
    }
}
