//! The layer-activation cache: CacheG generalized from adjacency masks
//! to per-layer node activations.
//!
//! One arena-backed store per GNN layer, sized at NodePad capacity so
//! `AddNode` never reallocates. Validity is **epoch-versioned**: a row is
//! live iff its stamp equals the store's current epoch, which makes
//! whole-cache invalidation O(1) (bump the epoch) while precise per-row
//! invalidation and revalidation stay O(rows touched) — exactly the
//! invalidation split the dirty frontier needs (mutations stale a few
//! rows; engine errors stale everything).

use crate::engine::kernels;

struct Layer {
    width: usize,
    /// Arena: `capacity × width`, row-major, allocated once.
    data: Vec<f32>,
    /// Row `i` is valid iff `row_epoch[i] == epoch`.
    row_epoch: Vec<u64>,
}

/// Per-layer activation store with epoch-versioned row validity.
pub struct ActivationCache {
    capacity: usize,
    epoch: u64,
    layers: Vec<Layer>,
}

impl ActivationCache {
    /// One store per layer; `widths[l]` is layer l's output width.
    pub fn new(capacity: usize, widths: &[usize]) -> ActivationCache {
        ActivationCache {
            capacity,
            // epoch 0 is the "never written" stamp, so start at 1
            epoch: 1,
            layers: widths
                .iter()
                .map(|&w| Layer {
                    width: w,
                    data: vec![0.0; capacity * w],
                    row_epoch: vec![0; capacity],
                })
                .collect(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn width(&self, layer: usize) -> usize {
        self.layers[layer].width
    }

    pub fn is_valid(&self, layer: usize, node: usize) -> bool {
        self.layers[layer].row_epoch[node] == self.epoch
    }

    /// Valid rows in a layer (gauge/debug).
    pub fn valid_rows(&self, layer: usize) -> usize {
        let l = &self.layers[layer];
        l.row_epoch.iter().filter(|&&e| e == self.epoch).count()
    }

    /// Read one valid row (`None` if stale) — the serving read.
    pub fn row(&self, layer: usize, node: usize) -> Option<&[f32]> {
        if !self.is_valid(layer, node) {
            return None;
        }
        let l = &self.layers[layer];
        Some(&l.data[node * l.width..(node + 1) * l.width])
    }

    /// Gather `nodes`' rows into the head of `out` (tile layout).
    /// Returns the number of **stale** rows gathered — 0 means every row
    /// was served by the cache; anything else means the caller's frontier
    /// invariant broke and the result must not be trusted.
    pub fn gather(&self, layer: usize, nodes: &[usize], out: &mut [f32]) -> usize {
        let l = &self.layers[layer];
        kernels::gather_rows(&l.data, l.width, nodes, out);
        nodes
            .iter()
            .filter(|&&n| l.row_epoch[n] != self.epoch)
            .count()
    }

    /// Scatter freshly-computed rows back (tile layout) and mark them
    /// valid — the write half of the partial-execution path.
    pub fn scatter(&mut self, layer: usize, nodes: &[usize], src: &[f32]) {
        let epoch = self.epoch;
        let l = &mut self.layers[layer];
        kernels::scatter_rows(&mut l.data, l.width, nodes, src);
        for &n in nodes {
            l.row_epoch[n] = epoch;
        }
    }

    /// Precisely stale out a set of rows in one layer (e.g. a shard
    /// marking non-owned final-layer rows it chose not to recompute).
    pub fn invalidate_rows(&mut self, layer: usize, nodes: &[usize]) {
        let l = &mut self.layers[layer];
        for &n in nodes {
            l.row_epoch[n] = 0;
        }
    }

    /// O(1) whole-cache invalidation: bump the epoch; every stamp goes
    /// stale at once.
    pub fn invalidate_all(&mut self) {
        self.epoch += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scatter_then_row_round_trips() {
        let mut c = ActivationCache::new(5, &[3, 2]);
        assert!(c.row(0, 2).is_none(), "rows start stale");
        c.scatter(0, &[2, 4], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(c.row(0, 2).unwrap(), &[1.0, 2.0, 3.0]);
        assert_eq!(c.row(0, 4).unwrap(), &[4.0, 5.0, 6.0]);
        assert!(c.row(0, 0).is_none());
        assert!(c.row(1, 2).is_none(), "layers are independent");
        assert_eq!(c.valid_rows(0), 2);
    }

    #[test]
    fn gather_counts_stale_rows() {
        let mut c = ActivationCache::new(4, &[2]);
        c.scatter(0, &[0, 1], &[1.0, 2.0, 3.0, 4.0]);
        let mut out = vec![0.0f32; 3 * 2];
        assert_eq!(c.gather(0, &[0, 1], &mut out), 0);
        assert_eq!(&out[..4], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(c.gather(0, &[0, 3, 1], &mut out), 1, "row 3 is stale");
    }

    #[test]
    fn epoch_bump_invalidates_everything_at_once() {
        let mut c = ActivationCache::new(3, &[2, 2]);
        c.scatter(0, &[0, 1, 2], &[0.0; 6]);
        c.scatter(1, &[0, 1, 2], &[0.0; 6]);
        assert_eq!(c.valid_rows(0) + c.valid_rows(1), 6);
        c.invalidate_all();
        assert_eq!(c.valid_rows(0) + c.valid_rows(1), 0);
        // rewrites under the new epoch become valid again
        c.scatter(1, &[1], &[7.0, 8.0]);
        assert!(c.is_valid(1, 1));
        assert!(!c.is_valid(1, 0));
    }

    #[test]
    fn precise_invalidation_is_per_row() {
        let mut c = ActivationCache::new(4, &[1]);
        c.scatter(0, &[0, 1, 2, 3], &[1.0, 2.0, 3.0, 4.0]);
        c.invalidate_rows(0, &[1, 3]);
        assert!(c.is_valid(0, 0) && c.is_valid(0, 2));
        assert!(!c.is_valid(0, 1) && !c.is_valid(0, 3));
    }
}
