//! GraphSplit: the CPU/NPU partitioner (paper §IV-A, Fig. 8).
//!
//! Control-flow tasks go to the CPU, data-parallel tasks to the NPU —
//! *except* when a Read-after-Write dependency would force an expensive
//! transfer back and forth. The partitioner starts from the per-op
//! preference of the offline [`CostModel`], then runs a local search that
//! flips placements (or whole same-stage groups) while total estimated
//! latency — compute plus every boundary-crossing edge — keeps improving.
//! Local search over a cost model is exactly what an offline calibration
//! pass can afford; optimal DAG partitioning is NP-hard.

use crate::npu::Placement;
use crate::ops::{OpGraph, OpKind};

use super::cost_model::CostModel;

/// A partitioning decision with its estimated cost.
#[derive(Debug, Clone)]
pub struct Partition {
    pub placement: Vec<Placement>,
    pub est_us: f64,
    /// Number of producer→consumer edges crossing the boundary.
    pub crossings: usize,
}

/// Estimated end-to-end latency of a placement: per-op device latency
/// plus transfer for every crossing edge. Graph inputs are host-resident
/// (they come from the application), so an accelerator op consuming a
/// *large* input pays the upload too — this is why naive "everything on
/// the NPU" loses, and why moving only half a RAW chain is punished.
pub fn estimate(g: &OpGraph, cm: &CostModel, placement: &[Placement]) -> (f64, usize) {
    let mut us = 0.0;
    let mut crossings = 0;
    for (id, op) in g.ops.iter().enumerate() {
        if op.kind == OpKind::Input {
            continue;
        }
        us += match placement[id] {
            Placement::Accel => cm.accel_us[id],
            Placement::Host => cm.host_us[id],
        };
        for &src in &op.inputs {
            let src_place = if g.ops[src].kind == OpKind::Input {
                // inputs live host-side; weights are small enough to be
                // preloaded (not charged per inference)
                if cm.out_bytes[src] <= 1 << 20 {
                    continue;
                }
                Placement::Host
            } else {
                placement[src]
            };
            if src_place != placement[id] {
                us += cm.xfer_us(src);
                crossings += 1;
            }
        }
    }
    (us, crossings)
}

/// Run GraphSplit on a graph: returns the chosen placement.
pub fn partition(g: &OpGraph, cm: &CostModel) -> Partition {
    // seed: every op on its individually-cheaper device
    let mut placement: Vec<Placement> = (0..g.len())
        .map(|id| {
            if g.ops[id].kind == OpKind::Input {
                Placement::Host
            } else if cm.cheaper_on_host(id) {
                Placement::Host
            } else {
                Placement::Accel
            }
        })
        .collect();

    let (mut best, _) = estimate(g, cm, &placement);
    // local search: single-op flips until fixpoint (bounded rounds)
    for _round in 0..8 {
        let mut improved = false;
        for id in 0..g.len() {
            if g.ops[id].kind == OpKind::Input {
                continue;
            }
            let old = placement[id];
            placement[id] = match old {
                Placement::Accel => Placement::Host,
                Placement::Host => Placement::Accel,
            };
            let (cand, _) = estimate(g, cm, &placement);
            if cand + 1e-12 < best {
                best = cand;
                improved = true;
            } else {
                placement[id] = old;
            }
        }
        if !improved {
            break;
        }
    }
    let (est_us, crossings) = estimate(g, cm, &placement);
    Partition { placement, est_us, crossings }
}

/// The trivial all-accelerator placement (the out-of-the-box mapping).
pub fn all_accel(g: &OpGraph) -> Vec<Placement> {
    g.ops
        .iter()
        .map(|op| {
            if op.kind == OpKind::Input {
                Placement::Host
            } else {
                Placement::Accel
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;
    use crate::ops::build::{gat, gcn_baseline, gcn_stagr, GatVariant, GnnDims};
    use crate::ops::Stage;

    fn split(g: &OpGraph) -> (CostModel, Partition) {
        let cm = CostModel::profile(
            g,
            &HardwareConfig::npu_series2(),
            &HardwareConfig::cpu(),
        );
        let p = partition(g, &cm);
        (cm, p)
    }

    #[test]
    fn gcn_preprocessing_lands_on_cpu_compute_on_npu() {
        let g = gcn_baseline(GnnDims::fig4(1354, 5429));
        let (_, p) = split(&g);
        // every preprocessing op (BuildAdj/Degrees/Sqrt/Div) → host
        for (id, op) in g.ops.iter().enumerate() {
            if op.kind == OpKind::Input {
                continue;
            }
            if op.stage == Stage::Preprocess {
                assert_eq!(
                    p.placement[id],
                    crate::npu::Placement::Host,
                    "{} should be host",
                    op.kind.name()
                );
            }
            // the big combination MatMuls stay on the accelerator
            if op.kind == OpKind::MatMul && g.ops[op.inputs[0]].shape[1] > 256 {
                assert_eq!(p.placement[id], crate::npu::Placement::Accel);
            }
        }
    }

    #[test]
    fn partition_beats_all_accel_baseline() {
        let g = gcn_baseline(GnnDims::fig4(1354, 5429));
        let (cm, p) = split(&g);
        let (base, _) = estimate(&g, &cm, &all_accel(&g));
        assert!(
            p.est_us < base,
            "GraphSplit {} must beat all-accel {}",
            p.est_us,
            base
        );
    }

    #[test]
    fn partition_beats_all_host_too() {
        let g = gcn_baseline(GnnDims::fig4(1354, 5429));
        let (cm, p) = split(&g);
        let all_host: Vec<Placement> = vec![Placement::Host; g.len()];
        let (host, _) = estimate(&g, &cm, &all_host);
        assert!(p.est_us < host, "GraphSplit {} vs all-host {}", p.est_us, host);
    }

    #[test]
    fn raw_dependencies_limit_crossings() {
        // the partition shouldn't ping-pong: crossings stay small
        let g = gat(GnnDims::fig4(1354, 5429), GatVariant::Baseline);
        let (_, p) = split(&g);
        assert!(
            p.crossings <= 8,
            "excessive boundary crossings: {}",
            p.crossings
        );
    }

    #[test]
    fn stagr_graph_stays_on_npu() {
        // with preprocessing already removed, nothing should move
        let g = gcn_stagr(GnnDims::fig4(1354, 5429), "stagr");
        let (_, p) = split(&g);
        let host_ops = g
            .ops
            .iter()
            .enumerate()
            .filter(|(id, op)| {
                op.kind != OpKind::Input && p.placement[*id] == Placement::Host
            })
            .count();
        assert_eq!(host_ops, 0, "StaGr graph is all data-parallel");
    }

    #[test]
    fn estimate_charges_crossings() {
        let g = gcn_baseline(GnnDims::fig4(256, 600));
        let cm = CostModel::profile(
            &g,
            &HardwareConfig::npu_series2(),
            &HardwareConfig::cpu(),
        );
        // place one mid-chain op on the host, its neighbors on accel
        let mut placement = all_accel(&g);
        let mid = g
            .ops
            .iter()
            .position(|op| op.kind == OpKind::MatMul)
            .unwrap();
        placement[mid] = Placement::Host;
        let (_, crossings) = estimate(&g, &cm, &placement);
        // the host op's output feeds an accel consumer → ≥1 crossing
        // (its own inputs may be host-resident already)
        assert!(crossings >= 1, "RAW chain must cross the boundary");
    }
}
