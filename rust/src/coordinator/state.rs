//! Model/graph state management: CacheG at the coordinator level.
//!
//! A [`ModelState`] owns the dataset, the trained weights, the dynamic
//! graph (GrAd), and the *cached derived masks* (PreG norm, GrAx1
//! neg-bias, SAGE sample). Masks are computed once on the CPU — the
//! GraphSplit placement of preprocessing — and reused across every
//! artifact execution until a GrAd update invalidates them (the CacheG
//! reuse story, lifted from SRAM to the coordinator). NodePad variants
//! are padded to the compiled capacity on demand and cached too.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::graph::{datasets::Dataset, dynamic::DynamicGraph, pad_features, Graph};
use crate::runtime::ArtifactInfo;
use crate::tensor::Tensor;

/// Cached, invalidation-tracked masks + weights for one dataset.
pub struct ModelState {
    pub dataset: Dataset,
    pub capacity: usize,
    /// Mutable graph (starts as the dataset's graph); GrAd updates land
    /// here and bump `version`.
    dynamic: DynamicGraph,
    version: u64,
    /// Weight tensors per model family ("gcn" → {w1, b1, …}).
    weights: BTreeMap<String, BTreeMap<String, Tensor>>,
    /// Mask cache keyed by (binding name, version).
    cache: BTreeMap<String, (u64, Tensor)>,
    /// Cache telemetry (CacheG hit accounting).
    pub cache_hits: usize,
    pub cache_misses: usize,
}

impl ModelState {
    /// Load dataset + all available model weights from the artifacts dir.
    pub fn load(dir: &Path, dataset_name: &str, capacity: usize) -> Result<ModelState> {
        let dataset = Dataset::load_gnnt(dir, dataset_name)?;
        let capacity = if capacity == 0 {
            crate::graph::datasets::spec(dataset_name)
                .map(|s| s.capacity)
                .unwrap_or(dataset.num_nodes())
        } else {
            capacity
        };
        let mut weights = BTreeMap::new();
        for model in ["gcn", "gat", "sage_mean", "sage_max"] {
            let path = dir.join(format!("weights_{model}_{dataset_name}.gnnt"));
            if path.exists() {
                weights.insert(
                    model.to_string(),
                    crate::runtime::io::read_gnnt(&path)
                        .with_context(|| format!("weights for {model}"))?,
                );
            }
        }
        let dynamic = DynamicGraph::new(&dataset.graph, capacity)?;
        Ok(ModelState {
            dataset,
            capacity,
            dynamic,
            version: 0,
            weights,
            cache: BTreeMap::new(),
            cache_hits: 0,
            cache_misses: 0,
        })
    }

    /// Construct directly from an in-memory dataset (tests, examples).
    pub fn from_dataset(dataset: Dataset, capacity: usize) -> Result<ModelState> {
        let capacity = capacity.max(dataset.num_nodes());
        let dynamic = DynamicGraph::new(&dataset.graph, capacity)?;
        Ok(ModelState {
            dataset,
            capacity,
            dynamic,
            version: 0,
            weights: BTreeMap::new(),
            cache: BTreeMap::new(),
            cache_hits: 0,
            cache_misses: 0,
        })
    }

    pub fn graph_version(&self) -> u64 {
        self.version
    }

    pub fn snapshot_graph(&self) -> Graph {
        self.dynamic.snapshot()
    }

    pub fn weights_for(&self, model: &str) -> Result<&BTreeMap<String, Tensor>> {
        self.weights
            .get(model)
            .ok_or_else(|| anyhow!("no weights loaded for model {model:?}"))
    }

    /// Test accuracy recorded at training time (from the weights file).
    pub fn trained_accuracy(&self, model: &str) -> Option<f32> {
        self.weights
            .get(model)?
            .get("test_acc")
            .and_then(|t| t.as_f32().ok())
            .and_then(|v| v.first().copied())
    }

    // ------------------------------------------------------------------
    // GrAd: runtime graph updates → cheap mask invalidation, no recompile
    // ------------------------------------------------------------------
    pub fn add_edge(&mut self, u: usize, v: usize) -> Result<bool> {
        let changed = self.dynamic.add_edge(u, v)?;
        if changed {
            self.invalidate();
        }
        Ok(changed)
    }

    pub fn remove_edge(&mut self, u: usize, v: usize) -> Result<bool> {
        let changed = self.dynamic.remove_edge(u, v)?;
        if changed {
            self.invalidate();
        }
        Ok(changed)
    }

    pub fn add_node(&mut self) -> Result<usize> {
        let id = self.dynamic.add_node()?;
        self.invalidate();
        Ok(id)
    }

    pub fn num_active_nodes(&self) -> usize {
        self.dynamic.num_nodes()
    }

    /// Live undirected edge count (density bookkeeping for the
    /// sparse-vs-dense aggregation decision).
    pub fn num_edges(&self) -> usize {
        self.dynamic.num_edges()
    }

    /// See [`DynamicGraph::dense_norm_materialized`].
    pub fn dense_norm_materialized(&self) -> bool {
        self.dynamic.dense_norm_materialized()
    }

    /// Live neighbor set of `u` from the dynamic graph (no snapshot).
    pub fn neighbors(&self, u: usize) -> &std::collections::BTreeSet<u32> {
        self.dynamic.neighbors(u)
    }

    /// The incrementally-maintained GrAd norm mask at full NodePad
    /// capacity — what the delta-driven engine's *dense* gather path
    /// reads, instead of rebuilding `norm_pad` O(capacity²) per update.
    /// Materializes the capacity² matrix on first call; the sparse path
    /// ([`ModelState::norm_csr`]) never does.
    pub fn norm_mask(&mut self) -> &crate::tensor::Mat {
        self.dynamic.norm()
    }

    /// The GrAd norm as a CSR operand at full NodePad capacity — the
    /// `SpMM` binding and the delta-driven engine's row-slice gather
    /// source. O(nnz) storage, refreshed O(n + m) per structure change.
    pub fn norm_csr(&mut self) -> &crate::tensor::CsrMat {
        self.dynamic.norm_csr()
    }

    fn invalidate(&mut self) {
        self.version += 1;
        // masks are recomputed lazily; weights/features survive
        self.cache.retain(|k, _| k.starts_with("x") || k == "edges");
    }

    // ------------------------------------------------------------------
    // Bindings (CacheG-cached mask/feature construction)
    // ------------------------------------------------------------------

    /// Produce the tensor for one artifact input name.
    pub fn binding(&mut self, name: &str, model: &str) -> Result<Tensor> {
        // weights first (never invalidated)
        if let Ok(w) = self.weights_for(model) {
            if let Some(t) = w.get(name) {
                return Ok(reshape_weight(name, t));
            }
        }
        let key = name.to_string();
        if let Some((ver, t)) = self.cache.get(&key) {
            if *ver == self.version {
                self.cache_hits += 1;
                return Ok(t.clone());
            }
        }
        self.cache_misses += 1;
        let n = self.dataset.num_nodes();
        let graph = self.dynamic.snapshot();
        let t = match name {
            "x" => Tensor::from_mat(&self.dataset.features),
            "x_pad" => Tensor::from_mat(&pad_features(
                &self.dataset.features,
                self.capacity,
            )),
            "norm" => Tensor::from_mat(&graph.norm_adjacency(n)),
            "norm_pad" => {
                Tensor::from_mat(&graph.norm_adjacency(self.capacity))
            }
            // CSR twins of the two masks above — what sparse (SpMM)
            // plans bind under the graph-input name "norm". O(nnz)
            // construction and storage; never materializes n².
            "norm_csr" => Tensor::from_csr(graph.norm_csr(n)),
            "norm_csr_pad" => Tensor::from_csr(graph.norm_csr(self.capacity)),
            "adj" => Tensor::from_mat(&graph.adjacency(n)),
            "neg_bias" => Tensor::from_mat(&graph.neg_bias(n)),
            "mask" => Tensor::from_mat(&graph.sampled_adjacency(
                crate::SAGE_MAX_NEIGHBORS,
                7,
                n,
            )),
            "nbr_idx" => self.nbr_idx_tensor()?,
            "edges" => {
                let mut data = Vec::with_capacity(graph.num_edges() * 2);
                for &(s, d) in graph.edges() {
                    data.push(s as i32);
                    data.push(d as i32);
                }
                Tensor::I32 { shape: vec![graph.num_edges(), 2], data }
            }
            other => bail!("unknown binding {other:?} for model {model:?}"),
        };
        self.cache.insert(key, (self.version, t.clone()));
        Ok(t)
    }

    /// All bindings for an artifact as a named map — the planned engine's
    /// native input format ([`crate::ops::exec::Bindings`]). Weight
    /// tensors keep their stored shapes (1-D biases are accepted by both
    /// the plan executor and the reference oracle's `to_mat`).
    pub fn bindings_map(&mut self, info: &ArtifactInfo)
                        -> Result<crate::ops::exec::Bindings> {
        let tensors = self.bindings_for(info)?;
        Ok(info.inputs.iter().cloned().zip(tensors).collect())
    }

    /// All bindings for an artifact, in its declared input order.
    pub fn bindings_for(&mut self, info: &ArtifactInfo) -> Result<Vec<Tensor>> {
        // older manifests recorded sage artifacts as model "sage"
        let model = if info.name.starts_with("sage_mean") {
            "sage_mean".to_string()
        } else if info.name.starts_with("sage_max") {
            "sage_max".to_string()
        } else {
            info.model.clone()
        };
        info.inputs
            .iter()
            .map(|name| self.binding(name, &model))
            .collect()
    }

    fn nbr_idx_tensor(&self) -> Result<Tensor> {
        // prefer the exact AOT-time sample (byte-identical gathers)
        if self.version == 0 {
            if let Some(idx) = &self.dataset.nbr_idx {
                return Ok(Tensor::I32 {
                    shape: vec![self.dataset.num_nodes(), self.dataset.nbr_width],
                    data: idx.clone(),
                });
            }
        }
        // regenerate after updates
        let graph = self.dynamic.snapshot();
        let rows = graph.sampled_neighbors(crate::SAGE_MAX_NEIGHBORS, 7);
        let w = crate::SAGE_MAX_NEIGHBORS + 1;
        let mut data = Vec::with_capacity(rows.len() * w);
        for row in rows {
            for j in row {
                data.push(j as i32);
            }
        }
        Ok(Tensor::I32 { shape: vec![graph.num_nodes(), w], data })
    }

    /// Densities of the structure masks (drives GraSp simulation).
    pub fn mask_densities(&self) -> BTreeMap<String, f64> {
        let n = self.dataset.num_nodes() as f64;
        let m = self.dynamic.num_edges() as f64;
        let mut out = BTreeMap::new();
        let adj_density = (2.0 * m + n) / (n * n);
        out.insert("norm".into(), adj_density);
        out.insert("norm_csr".into(), adj_density);
        let pad_density = (2.0 * m + n) / (self.capacity as f64).powi(2);
        out.insert("norm_pad".into(), pad_density);
        out.insert("norm_csr_pad".into(), pad_density);
        out.insert("adj".into(), adj_density);
        // neg_bias is dense-negative (non-zero where there is NO edge)
        out.insert("neg_bias".into(), 1.0 - adj_density);
        let k = (crate::SAGE_MAX_NEIGHBORS + 1) as f64;
        out.insert("mask".into(), (k * n).min(2.0 * m + n) / (n * n));
        out
    }
}

/// Artifact inputs are 2-D; weights files store 1-D biases/vectors.
/// Reshape on the way out so shapes match the manifest.
fn reshape_weight(name: &str, t: &Tensor) -> Tensor {
    match t {
        Tensor::F32 { shape, data } if shape.len() == 1 => {
            if name.starts_with('b') {
                // biases bind as (1, n) in the op-graph executor but the
                // HLO artifacts take them 1-D; keep 1-D (runtime shapes
                // come from the manifest, which recorded 1-D).
                Tensor::F32 { shape: shape.clone(), data: data.clone() }
            } else {
                t.clone()
            }
        }
        _ => t.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::synthesize;

    fn state() -> ModelState {
        let ds = synthesize("t", 40, 90, 4, 16, 3);
        ModelState::from_dataset(ds, 48).unwrap()
    }

    #[test]
    fn cacheg_hits_on_repeat_binding() {
        let mut s = state();
        let a = s.binding("neg_bias", "gat").unwrap();
        let b = s.binding("neg_bias", "gat").unwrap();
        assert_eq!(a, b);
        assert_eq!(s.cache_hits, 1);
        assert_eq!(s.cache_misses, 1);
    }

    #[test]
    fn grad_update_invalidates_masks_not_features() {
        let mut s = state();
        let before = s.binding("norm_pad", "gcn").unwrap();
        let x_before = s.binding("x_pad", "gcn").unwrap();
        s.add_edge(0, 5).unwrap();
        let after = s.binding("norm_pad", "gcn").unwrap();
        let x_after = s.binding("x_pad", "gcn").unwrap();
        assert_ne!(before, after, "norm must change after edge add");
        assert_eq!(x_before, x_after, "features survive structure updates");
    }

    #[test]
    fn duplicate_edge_does_not_invalidate() {
        let mut s = state();
        // edge (0,1) might not exist; add twice and compare versions
        s.add_edge(0, 1).unwrap();
        let v1 = s.graph_version();
        s.add_edge(0, 1).unwrap(); // duplicate → no change
        assert_eq!(s.graph_version(), v1);
    }

    #[test]
    fn padded_bindings_have_capacity_shape() {
        let mut s = state();
        let norm = s.binding("norm_pad", "gcn").unwrap();
        assert_eq!(norm.shape(), &[48, 48]);
        let x = s.binding("x_pad", "gcn").unwrap();
        assert_eq!(x.shape(), &[48, 16]);
    }

    #[test]
    fn csr_bindings_track_updates_and_match_dense() {
        let mut s = state();
        let csr = s.binding("norm_csr_pad", "gcn").unwrap();
        let dense = s.binding("norm_pad", "gcn").unwrap();
        assert_eq!(csr.shape(), &[48, 48]);
        assert_eq!(csr.to_mat().unwrap(), dense.to_mat().unwrap());
        // compressed bytes, not 48²·4
        assert!(csr.bytes() < dense.bytes());
        // CacheG: repeat binding hits the cache
        let misses = s.cache_misses;
        let again = s.binding("norm_csr_pad", "gcn").unwrap();
        assert_eq!(again, csr);
        assert_eq!(s.cache_misses, misses);
        // GrAd churn invalidates the CSR mask like the dense one
        s.add_edge(0, 7).unwrap();
        let after = s.binding("norm_csr_pad", "gcn").unwrap();
        assert_ne!(after, csr);
        assert_eq!(
            after.to_mat().unwrap(),
            s.binding("norm_pad", "gcn").unwrap().to_mat().unwrap()
        );
        // the live CSR accessor agrees with the binding
        assert_eq!(s.norm_csr(), after.as_csr().unwrap());
    }

    #[test]
    fn nodepad_capacity_enforced() {
        let mut s = state();
        for _ in 0..8 {
            s.add_node().unwrap();
        }
        assert!(s.add_node().is_err(), "capacity 48 = 40 + 8");
    }

    #[test]
    fn mask_densities_reflect_graph() {
        let s = state();
        let d = s.mask_densities();
        let norm_d = d["norm"];
        assert!(norm_d > 0.0 && norm_d < 0.2, "{norm_d}");
        assert!((d["neg_bias"] - (1.0 - norm_d)).abs() < 1e-12);
    }

    #[test]
    fn unknown_binding_is_error() {
        let mut s = state();
        assert!(s.binding("nonsense", "gcn").is_err());
    }

    #[test]
    fn edges_binding_matches_graph() {
        let mut s = state();
        let t = s.binding("edges", "gcn").unwrap();
        assert_eq!(t.shape()[0], s.snapshot_graph().num_edges());
        s.add_edge(2, 9).unwrap();
        let t2 = s.binding("edges", "gcn").unwrap();
        assert_eq!(t2.shape()[0], s.snapshot_graph().num_edges());
    }
}
