//! The GraNNite coordinator — Layer 3's core: it owns the PJRT runtime,
//! the per-dataset model state (weights + CacheG-cached masks + GrAd
//! dynamic graph), the GraphSplit cost model, and the request batcher.
//!
//! Numerics flow: CPU-side preprocessing (`graph::*` via
//! [`state::ModelState`]) → PJRT artifact execution ([`crate::runtime`]).
//! Timing flow: the same op graphs through the NPU simulator
//! ([`crate::npu`]) with the GraphSplit placement.

pub mod batcher;
pub mod cost_model;
pub mod graphsplit;
pub mod state;

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::HardwareConfig;
use crate::npu::{simulate, SimOptions, SimReport};
use crate::ops::build::{self, GnnDims};
use crate::runtime::Runtime;
use crate::tensor::Mat;

pub use batcher::{Batch, Batcher, Request};
pub use cost_model::CostModel;
pub use graphsplit::{partition, Partition};
pub use state::ModelState;

/// Everything needed to serve one dataset's models.
pub struct Coordinator {
    pub runtime: Runtime,
    pub state: ModelState,
}

impl Coordinator {
    /// Open artifacts + load the dataset/weights state.
    pub fn open(artifacts_dir: &Path, dataset: &str) -> Result<Coordinator> {
        let runtime = Runtime::open(artifacts_dir)?;
        let state = ModelState::load(artifacts_dir, dataset, 0)?;
        Ok(Coordinator { runtime, state })
    }

    /// [`Coordinator::open`] with an explicit worker pool — fleet shards
    /// pass [`crate::engine::WorkerPool::serial`] so N shard coordinators
    /// don't each spawn a machine-sized pool.
    pub fn open_with_pool(
        artifacts_dir: &Path,
        dataset: &str,
        pool: std::sync::Arc<crate::engine::WorkerPool>,
    ) -> Result<Coordinator> {
        let runtime = Runtime::open_with_pool(artifacts_dir, pool)?;
        let state = ModelState::load(artifacts_dir, dataset, 0)?;
        Ok(Coordinator { runtime, state })
    }

    /// Execute one artifact end-to-end on the current graph state and
    /// return the logits (planned-engine execution: the artifact's
    /// compiled [`crate::ops::plan::ExecPlan`] on a warm instance).
    pub fn infer(&mut self, artifact: &str) -> Result<Mat> {
        let info = self.runtime.artifact(artifact)?.clone();
        let bindings = self
            .state
            .bindings_map(&info)
            .with_context(|| format!("binding inputs for {artifact}"))?;
        let out = self.runtime.execute_named(artifact, &bindings)?;
        out.to_mat()
    }

    /// Test-set accuracy of an artifact's predictions.
    pub fn evaluate(&mut self, artifact: &str) -> Result<f64> {
        let logits = self.infer(artifact)?;
        let mask = self.state.dataset.test_mask.clone();
        Ok(self.state.dataset.accuracy(&logits, &mask))
    }

    /// Simulated latency/energy of a (model, variant) on given hardware,
    /// with the given GraNNite techniques and the real mask densities.
    pub fn simulate_variant(&self, model: &str, variant: &str,
                            hw: &HardwareConfig, opts: &SimOptions)
                            -> Result<SimReport> {
        let g = self.build_graph(model, variant)?;
        let mut opts = opts.clone();
        if opts.mask_density.is_empty() {
            opts.mask_density = self.state.mask_densities();
        }
        Ok(simulate(&g, hw, &opts))
    }

    /// Op graph of a model variant at this dataset's dimensions.
    pub fn build_graph(&self, model: &str, variant: &str) -> Result<crate::ops::OpGraph> {
        let ds = &self.state.dataset;
        let padded = matches!(variant, "grad" | "quant_grad");
        let n = if padded { self.state.capacity } else { ds.num_nodes() };
        let dims = GnnDims::model(
            n,
            ds.graph.num_edges(),
            ds.num_features(),
            ds.num_classes(),
        );
        let base_variant = match variant {
            "grad" => "stagr",
            "quant_grad" => "quant",
            v => v,
        };
        build::build(model, base_variant, dims)
    }

    /// Run GraphSplit for a model variant: cost model + partition.
    pub fn graphsplit(&self, model: &str, variant: &str,
                      accel: &HardwareConfig) -> Result<(crate::ops::OpGraph, Partition)> {
        let g = self.build_graph(model, variant)?;
        let cm = CostModel::profile(&g, accel, &HardwareConfig::cpu());
        let p = partition(&g, &cm);
        Ok((g, p))
    }

    /// Hand this coordinator's model state to the delta-driven serving
    /// engine ([`crate::incremental::IncrementalEngine`]). Weights come
    /// from the loaded artifact weight file (`weights_gcn_*.gnnt`) when
    /// present, else the deterministic offline synthesis. Consumes the
    /// coordinator: the engine takes ownership of the GrAd graph and
    /// CacheG state, which is the single-writer contract serving needs.
    pub fn into_incremental(
        self,
        cfg: crate::incremental::IncrementalConfig,
        pool: std::sync::Arc<crate::engine::WorkerPool>,
    ) -> Result<crate::incremental::IncrementalEngine> {
        let state = self.state;
        let weights: crate::ops::exec::Bindings = match state.weights_for("gcn") {
            Ok(w) => w
                .iter()
                .filter(|(k, _)| k.starts_with('w') || k.starts_with('b'))
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect(),
            Err(_) => crate::fleet::synthesize_weights(
                state.dataset.num_features(),
                state.dataset.num_classes().max(2),
                state.capacity,
            ),
        };
        let capacity = state.capacity;
        crate::incremental::IncrementalEngine::from_state(
            state,
            weights,
            0..capacity,
            pool,
            cfg,
        )
    }

    /// Resolve the artifact name for (model, variant) on this dataset.
    pub fn artifact_name(&self, model: &str, variant: &str) -> Result<String> {
        let ds = &self.state.dataset.name;
        let name = match (model, variant) {
            ("gcn", v) => format!("gcn_{v}_{ds}"),
            ("gat", v) => format!("gat_{v}_{ds}"),
            ("sage_mean", _) => format!("sage_mean_{ds}"),
            ("sage_max", "baseline") => format!("sage_max_baseline_{ds}"),
            ("sage_max", "grax3") => format!("sage_max_grax3_{ds}"),
            (m, v) => bail!("no artifact for {m}/{v}"),
        };
        Ok(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::synthesize;

    #[test]
    fn build_graph_uses_dataset_dims() {
        let ds = synthesize("t", 50, 120, 5, 24, 1);
        let state = ModelState::from_dataset(ds, 64).unwrap();
        // poke build_graph without a Runtime via a thin shim
        let dims = GnnDims::model(50, 120, 24, 5);
        let g = build::build("gcn", "stagr", dims).unwrap();
        g.validate().unwrap();
        assert_eq!(state.capacity, 64);
    }

    // Full Coordinator tests (PJRT execution) live in rust/tests/ —
    // they need `make artifacts` output.
}
