//! Dynamic request batcher for the serving path.
//!
//! Node-classification inference over a whole graph answers *every*
//! pending query in one pass, so the batcher's job is to coalesce query
//! arrivals between GrAd mask updates: requests accumulate until either
//! `max_batch` queries are waiting or the oldest has waited `max_wait`.
//! Structure updates are sequenced *before* the queries that arrive after
//! them (consistency: a query sees every update that preceded it).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One enqueued inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Node whose prediction the caller wants (None = full-graph).
    pub node: Option<usize>,
    pub enqueued: Instant,
}

/// A flushed batch.
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<Request>,
    /// Graph version the batch must execute at (≥ all updates seen).
    pub graph_version: u64,
}

#[derive(Debug, Default)]
struct Queue {
    pending: VecDeque<Request>,
    graph_version: u64,
    closed: bool,
}

/// Thread-safe batching queue.
pub struct Batcher {
    q: Mutex<Queue>,
    cv: Condvar,
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Batcher {
        assert!(max_batch > 0);
        Batcher {
            q: Mutex::new(Queue::default()),
            cv: Condvar::new(),
            max_batch,
            max_wait,
        }
    }

    /// Enqueue a query.
    pub fn submit(&self, req: Request) {
        let mut q = self.q.lock().unwrap();
        q.pending.push_back(req);
        self.cv.notify_all();
    }

    /// Record that a GrAd update has been applied (bumps the version any
    /// later batch must observe).
    pub fn note_update(&self, version: u64) {
        let mut q = self.q.lock().unwrap();
        q.graph_version = q.graph_version.max(version);
        self.cv.notify_all();
    }

    /// Close the queue; `next_batch` drains remaining requests then
    /// returns None.
    pub fn close(&self) {
        self.q.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn pending(&self) -> usize {
        self.q.lock().unwrap().pending.len()
    }

    /// Non-blocking: return a batch if the flush condition holds now.
    pub fn try_batch(&self) -> Option<Batch> {
        let mut q = self.q.lock().unwrap();
        if q.pending.is_empty() {
            return None;
        }
        let oldest = q.pending.front().unwrap().enqueued;
        if q.pending.len() >= self.max_batch
            || oldest.elapsed() >= self.max_wait
            || q.closed
        {
            let take = q.pending.len().min(self.max_batch);
            let requests: Vec<Request> = q.pending.drain(..take).collect();
            return Some(Batch { requests, graph_version: q.graph_version });
        }
        None
    }

    /// Block until a batch is ready (or the queue is closed and empty).
    pub fn next_batch(&self) -> Option<Batch> {
        let mut q = self.q.lock().unwrap();
        loop {
            if !q.pending.is_empty() {
                let oldest = q.pending.front().unwrap().enqueued;
                let full = q.pending.len() >= self.max_batch;
                let expired = oldest.elapsed() >= self.max_wait;
                if full || expired || q.closed {
                    let take = q.pending.len().min(self.max_batch);
                    let requests: Vec<Request> =
                        q.pending.drain(..take).collect();
                    return Some(Batch { requests, graph_version: q.graph_version });
                }
                // wait out the remainder of the batching window
                let remaining = self.max_wait.saturating_sub(oldest.elapsed());
                let (qq, _timeout) = self
                    .cv
                    .wait_timeout(q, remaining.min(Duration::from_millis(5)))
                    .unwrap();
                q = qq;
            } else if q.closed {
                return None;
            } else {
                q = self.cv.wait(q).unwrap();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64) -> Request {
        Request { id, node: None, enqueued: Instant::now() }
    }

    #[test]
    fn flushes_when_full() {
        let b = Batcher::new(3, Duration::from_secs(10));
        for i in 0..3 {
            b.submit(req(i));
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(batch.requests[0].id, 0);
    }

    #[test]
    fn flushes_on_timeout() {
        let b = Batcher::new(100, Duration::from_millis(20));
        b.submit(req(1));
        let start = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 1);
        assert!(start.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn batch_observes_latest_update_version() {
        let b = Batcher::new(2, Duration::from_secs(10));
        b.note_update(7);
        b.submit(req(1));
        b.submit(req(2));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.graph_version, 7);
    }

    #[test]
    fn close_drains_then_ends() {
        let b = Batcher::new(10, Duration::from_secs(10));
        b.submit(req(1));
        b.close();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn concurrent_producers_one_consumer() {
        let b = Arc::new(Batcher::new(16, Duration::from_millis(5)));
        let producers: Vec<_> = (0..4)
            .map(|t| {
                let b = b.clone();
                std::thread::spawn(move || {
                    for i in 0..50 {
                        b.submit(req(t * 1000 + i));
                    }
                })
            })
            .collect();
        let consumer = {
            let b = b.clone();
            std::thread::spawn(move || {
                let mut seen = 0;
                while seen < 200 {
                    if let Some(batch) = b.next_batch() {
                        seen += batch.requests.len();
                        assert!(batch.requests.len() <= 16);
                    }
                }
                seen
            })
        };
        for p in producers {
            p.join().unwrap();
        }
        assert_eq!(consumer.join().unwrap(), 200);
    }

    #[test]
    fn max_batch_respected_under_backlog() {
        let b = Batcher::new(4, Duration::from_millis(1));
        for i in 0..10 {
            b.submit(req(i));
        }
        let first = b.next_batch().unwrap();
        assert_eq!(first.requests.len(), 4);
        let second = b.next_batch().unwrap();
        assert_eq!(second.requests.len(), 4);
    }
}
