//! Dynamic request batcher for the serving path.
//!
//! Node-classification inference over a whole graph answers *every*
//! pending query in one pass, so the batcher's job is to coalesce query
//! arrivals between GrAd mask updates: requests accumulate until either
//! `max_batch` queries are waiting or `max_wait` has elapsed since the
//! **first** enqueue of the window. Structure updates are sequenced
//! *before* the queries that arrive after them (consistency: a query
//! sees every update that preceded it).
//!
//! The deadline is a hard one, anchored on the batcher's own clock at
//! the moment each request enters the queue — never on the
//! caller-supplied [`Request::enqueued`] stamp (which measures
//! client-side queueing and may be skewed), and never reset by later
//! arrivals. A trickle of requests therefore cannot starve a batch:
//! whatever arrives, the oldest waiter is flushed at most `max_wait`
//! after it entered.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One enqueued inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Node whose prediction the caller wants (None = full-graph).
    pub node: Option<usize>,
    pub enqueued: Instant,
}

/// A flushed batch.
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<Request>,
    /// Graph version the batch must execute at (≥ all updates seen).
    pub graph_version: u64,
}

#[derive(Debug, Default)]
struct Queue {
    pending: VecDeque<Request>,
    /// Batcher-observed arrival time of each pending request (aligned
    /// with `pending`); the flush deadline is `arrivals.front() +
    /// max_wait`.
    arrivals: VecDeque<Instant>,
    graph_version: u64,
    closed: bool,
}

impl Queue {
    /// True when the flush condition holds now.
    fn ready(&self, max_batch: usize, max_wait: Duration) -> bool {
        match self.arrivals.front() {
            None => false,
            Some(first) => {
                self.pending.len() >= max_batch
                    || first.elapsed() >= max_wait
                    || self.closed
            }
        }
    }

    fn flush(&mut self, max_batch: usize) -> Batch {
        let take = self.pending.len().min(max_batch);
        let requests: Vec<Request> = self.pending.drain(..take).collect();
        self.arrivals.drain(..take);
        Batch { requests, graph_version: self.graph_version }
    }
}

/// Thread-safe batching queue.
pub struct Batcher {
    q: Mutex<Queue>,
    cv: Condvar,
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Batcher {
        assert!(max_batch > 0);
        Batcher {
            q: Mutex::new(Queue::default()),
            cv: Condvar::new(),
            max_batch,
            max_wait,
        }
    }

    /// Enqueue a query. The flush deadline for this request starts *now*,
    /// on the batcher's clock.
    pub fn submit(&self, req: Request) {
        let mut q = self.q.lock().unwrap();
        q.pending.push_back(req);
        q.arrivals.push_back(Instant::now());
        self.cv.notify_all();
    }

    /// Record that a GrAd update has been applied (bumps the version any
    /// later batch must observe).
    pub fn note_update(&self, version: u64) {
        let mut q = self.q.lock().unwrap();
        q.graph_version = q.graph_version.max(version);
        self.cv.notify_all();
    }

    /// Close the queue; `next_batch` drains remaining requests then
    /// returns None.
    pub fn close(&self) {
        self.q.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn pending(&self) -> usize {
        self.q.lock().unwrap().pending.len()
    }

    /// Non-blocking: return a batch if the flush condition holds now.
    pub fn try_batch(&self) -> Option<Batch> {
        let mut q = self.q.lock().unwrap();
        if q.ready(self.max_batch, self.max_wait) {
            Some(q.flush(self.max_batch))
        } else {
            None
        }
    }

    /// Block until a batch is ready (or the queue is closed and empty).
    pub fn next_batch(&self) -> Option<Batch> {
        let mut q = self.q.lock().unwrap();
        loop {
            if q.ready(self.max_batch, self.max_wait) {
                return Some(q.flush(self.max_batch));
            }
            if let Some(first) = q.arrivals.front() {
                // wait out the remainder of the batching window; the cap
                // keeps us responsive to max_batch fills signaled late
                let remaining = self.max_wait.saturating_sub(first.elapsed());
                let (qq, _timeout) = self
                    .cv
                    .wait_timeout(q, remaining.min(Duration::from_millis(5)))
                    .unwrap();
                q = qq;
            } else if q.closed {
                return None;
            } else {
                q = self.cv.wait(q).unwrap();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(id: u64) -> Request {
        Request { id, node: None, enqueued: Instant::now() }
    }

    #[test]
    fn flushes_when_full() {
        let b = Batcher::new(3, Duration::from_secs(10));
        for i in 0..3 {
            b.submit(req(i));
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(batch.requests[0].id, 0);
    }

    #[test]
    fn flushes_on_timeout() {
        let b = Batcher::new(100, Duration::from_millis(20));
        b.submit(req(1));
        let start = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 1);
        assert!(start.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn batch_observes_latest_update_version() {
        let b = Batcher::new(2, Duration::from_secs(10));
        b.note_update(7);
        b.submit(req(1));
        b.submit(req(2));
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.graph_version, 7);
    }

    #[test]
    fn close_drains_then_ends() {
        let b = Batcher::new(10, Duration::from_secs(10));
        b.submit(req(1));
        b.close();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.requests.len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn concurrent_producers_one_consumer() {
        let b = Arc::new(Batcher::new(16, Duration::from_millis(5)));
        let producers: Vec<_> = (0..4)
            .map(|t| {
                let b = b.clone();
                std::thread::spawn(move || {
                    for i in 0..50 {
                        b.submit(req(t * 1000 + i));
                    }
                })
            })
            .collect();
        let consumer = {
            let b = b.clone();
            std::thread::spawn(move || {
                let mut seen = 0;
                while seen < 200 {
                    if let Some(batch) = b.next_batch() {
                        seen += batch.requests.len();
                        assert!(batch.requests.len() <= 16);
                    }
                }
                seen
            })
        };
        for p in producers {
            p.join().unwrap();
        }
        assert_eq!(consumer.join().unwrap(), 200);
    }

    #[test]
    fn max_batch_respected_under_backlog() {
        let b = Batcher::new(4, Duration::from_millis(1));
        for i in 0..10 {
            b.submit(req(i));
        }
        std::thread::sleep(Duration::from_millis(2));
        let first = b.next_batch().unwrap();
        assert_eq!(first.requests.len(), 4);
        let second = b.next_batch().unwrap();
        assert_eq!(second.requests.len(), 4);
    }

    /// Regression (hard-deadline satellite): the flush deadline is the
    /// batcher's own arrival clock. A caller-supplied `enqueued` stamp in
    /// the future — clock skew, or a re-stamped retry — must not defer
    /// the flush past `max_wait`.
    #[test]
    fn skewed_enqueued_stamp_cannot_defer_flush() {
        let b = Batcher::new(100, Duration::from_millis(30));
        b.submit(Request {
            id: 1,
            node: None,
            enqueued: Instant::now() + Duration::from_secs(3600),
        });
        let start = Instant::now();
        let deadline = Duration::from_secs(2);
        loop {
            if let Some(batch) = b.try_batch() {
                assert_eq!(batch.requests.len(), 1);
                break;
            }
            assert!(
                start.elapsed() < deadline,
                "flush deferred past max_wait by a skewed enqueue stamp"
            );
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(start.elapsed() >= Duration::from_millis(25));
    }

    /// Regression (hard-deadline satellite): a trickle of later arrivals
    /// cannot extend the first waiter's deadline — the batch flushes at
    /// `first enqueue + max_wait` even while requests keep landing.
    #[test]
    fn trickle_cannot_extend_deadline() {
        let b = Arc::new(Batcher::new(1000, Duration::from_millis(40)));
        let producer = {
            let b = b.clone();
            std::thread::spawn(move || {
                // first enqueue starts the window; then trickle forever
                // (well past the deadline)
                for i in 0..30 {
                    b.submit(req(i));
                    std::thread::sleep(Duration::from_millis(5));
                }
            })
        };
        let start = Instant::now();
        let batch = b.next_batch().unwrap();
        let waited = start.elapsed();
        assert!(
            waited < Duration::from_millis(120),
            "trickle starved the batch for {waited:?}"
        );
        assert!(
            batch.requests.len() < 30,
            "flush must not wait for the whole trickle"
        );
        assert_eq!(batch.requests[0].id, 0);
        producer.join().unwrap();
    }
}
