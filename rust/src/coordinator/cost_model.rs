//! GraphSplit's offline cost model (paper §IV-A).
//!
//! "GraNNite introduces an offline profiling phase during model
//! calibration. In this phase, we build a cost model that measures
//! latencies of various operations on both the CPU and NPU [and] the
//! overhead from data transfer and communication."
//!
//! Per op we tabulate: accelerator latency, host latency, and the
//! transfer cost of every producer→consumer edge that would cross the
//! boundary. The partitioner ([`super::graphsplit`]) consumes this table.

use crate::config::HardwareConfig;
use crate::npu::cost::{op_cost, CostOpts};
use crate::ops::{OpGraph, OpKind};

/// Cost table for one (graph, accelerator, host) triple.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Accelerator latency per op (µs), Input ops = 0.
    pub accel_us: Vec<f64>,
    /// Host latency per op (µs).
    pub host_us: Vec<f64>,
    /// Bytes of each op's output (for boundary-crossing costs).
    pub out_bytes: Vec<usize>,
    /// Link parameters (from the accelerator's config).
    pub xfer_gbps: f64,
    pub xfer_setup_us: f64,
}

impl CostModel {
    /// Build the table by probing both device models.
    pub fn profile(g: &OpGraph, accel: &HardwareConfig,
                   host: &HardwareConfig) -> CostModel {
        let opts = CostOpts { dense_dtype_bytes: 2, ..Default::default() };
        let host_opts = CostOpts { dense_dtype_bytes: 4, ..Default::default() };
        let mut accel_us = Vec::with_capacity(g.len());
        let mut host_us = Vec::with_capacity(g.len());
        let mut out_bytes = Vec::with_capacity(g.len());
        for id in g.topo_order() {
            let op = &g.ops[id];
            if op.kind == OpKind::Input {
                accel_us.push(0.0);
                host_us.push(0.0);
            } else {
                let engine = op.kind.default_engine();
                accel_us.push(op_cost(g, id, accel, engine, opts).us);
                host_us.push(op_cost(g, id, host, engine, host_opts).us);
            }
            out_bytes.push(op.bytes());
        }
        CostModel {
            accel_us,
            host_us,
            out_bytes,
            xfer_gbps: accel.xfer_gbps,
            xfer_setup_us: accel.xfer_setup_us,
        }
    }

    /// Transfer cost of moving op `id`'s output across the boundary.
    pub fn xfer_us(&self, id: usize) -> f64 {
        self.xfer_setup_us + self.out_bytes[id] as f64 / (self.xfer_gbps * 1e3)
    }

    /// Where the cost model would run op `id` in isolation (no transfer).
    pub fn cheaper_on_host(&self, id: usize) -> bool {
        self.host_us[id] < self.accel_us[id]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::build::{gcn_baseline, GnnDims};
    use crate::ops::Stage;

    fn model() -> (OpGraph, CostModel) {
        let g = gcn_baseline(GnnDims::fig4(512, 1500));
        let cm = CostModel::profile(
            &g,
            &HardwareConfig::npu_series2(),
            &HardwareConfig::cpu(),
        );
        (g, cm)
    }

    #[test]
    fn control_heavy_preprocessing_cheaper_on_host() {
        let (g, cm) = model();
        // the adjacency build / norm divisions should prefer the CPU
        for (id, op) in g.ops.iter().enumerate() {
            if op.stage == Stage::Preprocess
                && matches!(op.kind, OpKind::AdjacencyFromEdges | OpKind::Div)
            {
                assert!(
                    cm.cheaper_on_host(id),
                    "{} should be cheaper on host ({} vs {})",
                    op.kind.name(),
                    cm.host_us[id],
                    cm.accel_us[id]
                );
            }
        }
    }

    #[test]
    fn dense_matmul_cheaper_on_accel() {
        let (g, cm) = model();
        let mut found = false;
        for (id, op) in g.ops.iter().enumerate() {
            if op.kind == OpKind::MatMul && g.ops[op.inputs[0]].shape[1] > 256 {
                assert!(!cm.cheaper_on_host(id), "big matmul belongs on NPU");
                found = true;
            }
        }
        assert!(found);
    }

    #[test]
    fn xfer_cost_scales_with_bytes() {
        let (g, cm) = model();
        let small = g
            .ops
            .iter()
            .position(|op| op.shape == vec![512, 1])
            .unwrap();
        let big = g
            .ops
            .iter()
            .position(|op| op.shape == vec![512, 512])
            .unwrap();
        assert!(cm.xfer_us(big) > cm.xfer_us(small));
        assert!(cm.xfer_us(small) >= cm.xfer_setup_us);
    }

    #[test]
    fn inputs_are_free() {
        let (g, cm) = model();
        for (id, op) in g.ops.iter().enumerate() {
            if op.kind == OpKind::Input {
                assert_eq!(cm.accel_us[id], 0.0);
                assert_eq!(cm.host_us[id], 0.0);
            }
        }
    }
}
