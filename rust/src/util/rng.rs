//! Deterministic PRNG (xoshiro256**), the randomness substrate for the
//! dataset twins, workload generators, and property tests.
//!
//! The crates.io `rand` family is unavailable offline, so this is a small,
//! well-known generator with splittable seeding. Determinism matters more
//! than statistical perfection here: every experiment in EXPERIMENTS.md
//! must be exactly reproducible from its seed.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so nearby seeds give uncorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        // All-zero state is invalid; SplitMix64 of any seed avoids it, but
        // guard anyway.
        let s = if s == [0; 4] { [1, 2, 3, 4] } else { s };
        Rng { s }
    }

    /// Derive an independent stream (for per-worker/per-test seeding).
    pub fn split(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift (unbiased
    /// enough for simulation workloads; exact rejection not needed).
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.usize(hi - lo)
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (used for synthetic feature noise).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential inter-arrival with rate `lambda` (Poisson workloads).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn usize_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let x = r.usize(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
        assert!(sorted.iter().all(|&i| i < 50));
    }

    #[test]
    #[should_panic(expected = "sample")]
    fn sample_more_than_population_panics() {
        Rng::new(0).sample_indices(3, 4);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn split_streams_uncorrelated() {
        let mut base = Rng::new(9);
        let mut a = base.split(0);
        let mut b = base.split(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
