//! Allocation-counting global allocator — the test hook that proves the
//! planned engine's zero-steady-state-allocation claim.
//!
//! Install it from a *dedicated* integration-test binary (so unrelated
//! parallel tests don't pollute the counter):
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: grannite::util::alloc::CountingAlloc =
//!     grannite::util::alloc::CountingAlloc;
//! // ... warm up ... let before = allocation_count(); ... run ...
//! assert_eq!(allocation_count() - before, 0);
//! ```

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

/// Process-wide count of `alloc`/`realloc` calls since start.
pub fn allocation_count() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

/// A [`System`]-delegating allocator that counts allocation events.
pub struct CountingAlloc;

// SAFETY: pure delegation to `System`; the counter has no effect on the
// returned memory.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}
