//! 64-byte-aligned slab allocation for the engine arena.
//!
//! The SIMD microkernels stream arena slabs with 8-wide (32-byte)
//! vector loads; a slab whose base address straddles a cache line turns
//! every such load into two line fetches. `AlignedBuf` replaces the
//! arena's `Box<[T]>` slabs with allocations pinned to [`SLAB_ALIGN`],
//! so vector lane 0 of every row block starts on a cache-line boundary.
//! Allocation happens once at plan-instance construction — the
//! zero-steady-state-allocation contract (`rust/tests/plan_alloc.rs`)
//! is unchanged.

use std::alloc::{alloc_zeroed, dealloc, handle_alloc_error, Layout};
use std::ptr::NonNull;

/// Arena slab base alignment in bytes: one x86 cache line, and 8× the
/// engine's 8-lane f32 vector width.
pub const SLAB_ALIGN: usize = 64;

mod private {
    /// Seals [`super::Zeroed`]: only element types audited for the
    /// all-zero bit pattern may back an [`super::AlignedBuf`].
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i8 {}
}

/// Element types whose all-zero byte pattern is a valid value, so
/// `alloc_zeroed` yields an initialized buffer. Sealed — implemented for
/// the arena's element types (`f32`, `i8`) only.
pub trait Zeroed: Copy + private::Sealed {}
impl Zeroed for f32 {}
impl Zeroed for i8 {}

/// A heap slab of `T` with [`SLAB_ALIGN`]-byte base alignment. Behaves
/// like a fixed-size `Box<[T]>` (derefs to a slice); zero-length buffers
/// allocate nothing.
pub struct AlignedBuf<T: Zeroed> {
    ptr: NonNull<T>,
    len: usize,
}

impl<T: Zeroed> AlignedBuf<T> {
    /// Zero-initialized slab of `len` elements.
    pub fn zeroed(len: usize) -> AlignedBuf<T> {
        if len == 0 {
            return AlignedBuf { ptr: NonNull::dangling(), len: 0 };
        }
        let layout = Self::layout(len);
        // SAFETY: `layout` has nonzero size (len > 0, T is f32/i8);
        // `Zeroed` guarantees the all-zero pattern is a valid T.
        let raw = unsafe { alloc_zeroed(layout) } as *mut T;
        let Some(ptr) = NonNull::new(raw) else {
            handle_alloc_error(layout)
        };
        AlignedBuf { ptr, len }
    }

    fn layout(len: usize) -> Layout {
        Layout::from_size_align(
            len * std::mem::size_of::<T>(),
            SLAB_ALIGN.max(std::mem::align_of::<T>()),
        )
        .expect("slab layout overflow")
    }

    /// Base pointer (aligned to [`SLAB_ALIGN`] for non-empty buffers).
    pub fn as_ptr(&self) -> *const T {
        self.ptr.as_ptr()
    }
}

impl<T: Zeroed> Default for AlignedBuf<T> {
    /// Empty buffer — no allocation; what `std::mem::take` leaves behind
    /// when the engine temporarily moves a slab out of the arena.
    fn default() -> Self {
        AlignedBuf::zeroed(0)
    }
}

impl<T: Zeroed> std::ops::Deref for AlignedBuf<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        // SAFETY: ptr is valid for len elements (or dangling with len 0,
        // which from_raw_parts permits), initialized by alloc_zeroed.
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl<T: Zeroed> std::ops::DerefMut for AlignedBuf<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        // SAFETY: as in Deref; &mut self guarantees uniqueness.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl<T: Zeroed> Drop for AlignedBuf<T> {
    fn drop(&mut self) {
        if self.len > 0 {
            // SAFETY: allocated in `zeroed` with this exact layout.
            unsafe {
                dealloc(self.ptr.as_ptr() as *mut u8, Self::layout(self.len));
            }
        }
    }
}

// SAFETY: AlignedBuf owns its allocation exclusively, like Box<[T]>.
unsafe impl<T: Zeroed + Send> Send for AlignedBuf<T> {}
unsafe impl<T: Zeroed + Sync> Sync for AlignedBuf<T> {}

impl<T: Zeroed + std::fmt::Debug> std::fmt::Debug for AlignedBuf<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AlignedBuf").field("len", &self.len).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slabs_are_aligned_and_zeroed() {
        for len in [1usize, 7, 64, 1000] {
            let buf: AlignedBuf<f32> = AlignedBuf::zeroed(len);
            assert_eq!(buf.as_ptr() as usize % SLAB_ALIGN, 0, "len {len}");
            assert_eq!(buf.len(), len);
            assert!(buf.iter().all(|&v| v == 0.0));
        }
        let buf: AlignedBuf<i8> = AlignedBuf::zeroed(33);
        assert_eq!(buf.as_ptr() as usize % SLAB_ALIGN, 0);
        assert!(buf.iter().all(|&v| v == 0));
    }

    #[test]
    fn mutation_roundtrips() {
        let mut buf: AlignedBuf<f32> = AlignedBuf::zeroed(16);
        for (i, v) in buf.iter_mut().enumerate() {
            *v = i as f32;
        }
        assert_eq!(buf[10], 10.0);
        buf.fill(0.0);
        assert!(buf.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn empty_buffer_allocates_nothing_and_derefs() {
        let buf: AlignedBuf<f32> = AlignedBuf::default();
        assert!(buf.is_empty());
        assert_eq!(&buf[..], &[] as &[f32]);
    }
}
