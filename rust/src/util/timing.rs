//! Wall-clock measurement helpers used by the bench harness and the
//! coordinator's online cost model.

use std::time::Instant;

/// Measure one invocation, returning (result, elapsed µs).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed().as_secs_f64() * 1e6)
}

/// Robust summary statistics over a latency sample (µs).
#[derive(Debug, Clone, PartialEq)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Stats {
    pub fn from_samples(samples: &[f64]) -> Stats {
        assert!(!samples.is_empty(), "no samples");
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        let q = |p: f64| -> f64 {
            let idx = (p * (n - 1) as f64).round() as usize;
            sorted[idx.min(n - 1)]
        };
        Stats {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: q(0.50),
            p95: q(0.95),
            p99: q(0.99),
            max: sorted[n - 1],
        }
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={} p50={} p95={} p99={} max={}",
            self.n,
            super::human_us(self.mean),
            super::human_us(self.p50),
            super::human_us(self.p95),
            super::human_us(self.p99),
            super::human_us(self.max),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_constant_sample() {
        let s = Stats::from_samples(&[5.0; 10]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 5.0);
        assert_eq!(s.p99, 5.0);
    }

    #[test]
    fn stats_percentiles_ordered() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Stats::from_samples(&samples);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99);
        assert!((s.mean - 50.5).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "no samples")]
    fn stats_empty_panics() {
        Stats::from_samples(&[]);
    }

    #[test]
    fn time_once_measures() {
        let (v, us) = time_once(|| {
            std::thread::sleep(std::time::Duration::from_millis(2));
            42
        });
        assert_eq!(v, 42);
        assert!(us >= 2_000.0, "measured {us}");
    }
}
