//! A minimal property-testing harness (proptest is unavailable offline).
//!
//! Deliberately small: deterministic seeds, N cases per property, and a
//! failure report that prints the seed + case index so any counterexample
//! is replayable with `case_rng(seed, i)`. No shrinking — generators are
//! kept small-biased instead, which in practice finds the same bugs.
//!
//! ```no_run
//! use grannite::util::propcheck::{forall, Gen};
//! forall("sum is commutative", 64, |g| {
//!     let a = g.small_f32();
//!     let b = g.small_f32();
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Rng;

/// Generator handed to each property case.
pub struct Gen {
    rng: Rng,
    /// Human-readable trace of the values drawn, printed on failure.
    trace: Vec<String>,
}

impl Gen {
    pub fn new(rng: Rng) -> Self {
        Gen { rng, trace: Vec::new() }
    }

    fn note(&mut self, label: &str, v: impl std::fmt::Debug) {
        if self.trace.len() < 64 {
            self.trace.push(format!("{label}={v:?}"));
        }
    }

    /// Dimension-like size, biased small: 1..=max with extra mass near 1
    /// and near block boundaries (the interesting edges for tiling code).
    pub fn dim(&mut self, max: usize) -> usize {
        let v = match self.rng.usize(10) {
            0 => 1,
            1 => max,
            2 => {
                // near a power of two
                let p = 1usize << self.rng.range(0, 8);
                (p + self.rng.range(0, 3)).clamp(1, max)
            }
            _ => self.rng.range(1, max + 1),
        };
        self.note("dim", v);
        v
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        let v = self.rng.range(lo, hi);
        self.note("usize", v);
        v
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.chance(0.5);
        self.note("bool", v);
        v
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// f32 in a tame range, including exact zero sometimes.
    pub fn small_f32(&mut self) -> f32 {
        let v = match self.rng.usize(8) {
            0 => 0.0,
            1 => 1.0,
            2 => -1.0,
            _ => (self.rng.f64() * 8.0 - 4.0) as f32,
        };
        self.note("f32", v);
        v
    }

    /// Vector of tame f32s.
    pub fn vec_f32(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| (self.rng.f64() * 4.0 - 2.0) as f32).collect()
    }

    /// Non-negative f32 vector (post-ReLU-like data for GrAx3 laws).
    pub fn vec_f32_nonneg(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| (self.rng.f64() * 4.0) as f32).collect()
    }

    /// Access the underlying RNG for custom generators.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// PRNG for property case `i` of the property seeded by `seed`.
pub fn case_rng(seed: u64, case: usize) -> Rng {
    Rng::new(seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Run `cases` deterministic cases of a property. Panics (with replay
/// info) on the first failing case.
pub fn forall(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen)) {
    // Stable per-property seed derived from the name.
    let seed = name
        .bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3)
        });
    for case in 0..cases {
        let mut g = Gen::new(case_rng(seed, case));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g)
        }));
        if let Err(payload) = result {
            eprintln!(
                "property '{name}' failed at case {case} (seed {seed:#x})\n  drawn: [{}]",
                g.trace.join(", ")
            );
            std::panic::resume_unwind(payload);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0;
        forall("counting", 32, |_| count += 1);
        assert_eq!(count, 32);
    }

    #[test]
    fn forall_is_deterministic() {
        let mut first: Vec<usize> = Vec::new();
        forall("det", 16, |g| first.push(g.usize(0, 1000)));
        let mut second: Vec<usize> = Vec::new();
        forall("det", 16, |g| second.push(g.usize(0, 1000)));
        assert_eq!(first, second);
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failure() {
        forall("fails", 8, |g| {
            let x = g.usize(0, 10);
            assert!(x < 5, "found the planted bug");
        });
    }

    #[test]
    fn dim_hits_edges() {
        let mut saw_one = false;
        let mut saw_max = false;
        forall("edges", 256, |g| {
            let d = g.dim(64);
            assert!((1..=64).contains(&d));
            saw_one |= d == 1;
            saw_max |= d == 64;
        });
        assert!(saw_one && saw_max);
    }
}
