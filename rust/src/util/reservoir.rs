//! Bounded reservoir sampling (Algorithm R) for long-lived metric sinks.
//!
//! A serving deployment runs indefinitely; the metrics layer used to push
//! every latency sample into an unbounded `Vec`, which is a slow leak.
//! [`Reservoir`] keeps a fixed-capacity uniform sample for percentile
//! estimation while tracking the *exact* count, sum, min and max — so
//! `n`, `mean`, `min` and `max` in a derived [`Stats`] are exact no
//! matter how many samples passed through, and only the percentiles
//! degrade (gracefully, to a uniform subsample) past capacity.
//!
//! Determinism: the replacement stream comes from the crate's own
//! [`Rng`], seeded at construction, so two runs that feed the same
//! sample sequence produce the same reservoir (tested below and in
//! `rust/src/metrics/mod.rs`).

use super::timing::Stats;
use super::Rng;

/// Fixed-capacity uniform sample with exact count/sum/min/max.
#[derive(Debug, Clone)]
pub struct Reservoir {
    cap: usize,
    seen: usize,
    sum: f64,
    min: f64,
    max: f64,
    samples: Vec<f64>,
    rng: Rng,
}

impl Reservoir {
    /// A reservoir holding at most `cap` samples (`cap` ≥ 1 enforced),
    /// with a deterministic replacement stream from `seed`.
    pub fn new(cap: usize, seed: u64) -> Reservoir {
        let cap = cap.max(1);
        Reservoir {
            cap,
            seen: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            samples: Vec::with_capacity(cap),
            rng: Rng::new(seed),
        }
    }

    /// Record one observation (Algorithm R: the t-th item replaces a
    /// random slot with probability cap/t once the reservoir is full).
    pub fn record(&mut self, x: f64) {
        self.seen += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if self.samples.len() < self.cap {
            self.samples.push(x);
        } else {
            let j = self.rng.usize(self.seen);
            if j < self.cap {
                self.samples[j] = x;
            }
        }
    }

    /// Exact number of observations recorded (not the retained count).
    pub fn seen(&self) -> usize {
        self.seen
    }

    /// True if nothing was ever recorded.
    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    /// Exact running sum.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact minimum (`+inf` when empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Exact maximum (`-inf` when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// The retained uniform subsample (equals the full stream while
    /// `seen ≤ cap`).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Summary statistics: exact `n`/`mean`/`min`/`max`, percentiles and
    /// std estimated from the retained subsample. `None` when empty.
    pub fn stats(&self) -> Option<Stats> {
        if self.seen == 0 {
            return None;
        }
        let mut s = Stats::from_samples(&self.samples);
        s.n = self.seen;
        s.mean = self.sum / self.seen as f64;
        s.min = self.min;
        s.max = self.max;
        Some(s)
    }

    /// Interpolated quantile estimate over the retained subsample
    /// (linear between closest ranks — the SLO monitor's latency
    /// objective check). `q` is clamped to `[0, 1]`; `None` when empty.
    /// Exact while `seen ≤ cap`, a uniform-subsample estimate past it.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.samples.is_empty() {
            return None;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(quantile_sorted(&sorted, q))
    }
}

/// Linear-interpolation quantile over an already-sorted non-empty slice
/// (the reference definition [`Reservoir::quantile`] and the monitor's
/// history windows share).
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] + (sorted[hi.min(sorted.len() - 1)] - sorted[lo]) * frac
}

/// Stats over the union of several reservoirs (the `Metrics::merged`
/// path): exact totals are summed, percentiles come from the pooled
/// subsamples — consistent with per-sink [`Reservoir::stats`] when every
/// sink is below capacity.
pub fn merged_stats(parts: &[&Reservoir]) -> Option<Stats> {
    let seen: usize = parts.iter().map(|r| r.seen()).sum();
    if seen == 0 {
        return None;
    }
    let pooled: Vec<f64> = parts
        .iter()
        .flat_map(|r| r.samples().iter().copied())
        .collect();
    let mut s = Stats::from_samples(&pooled);
    s.n = seen;
    s.mean = parts.iter().map(|r| r.sum()).sum::<f64>() / seen as f64;
    s.min = parts.iter().map(|r| r.min()).fold(f64::INFINITY, f64::min);
    s.max = parts.iter().map(|r| r.max()).fold(f64::NEG_INFINITY, f64::max);
    Some(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn below_capacity_is_exact() {
        let mut r = Reservoir::new(100, 7);
        for i in 1..=50 {
            r.record(i as f64);
        }
        let s = r.stats().unwrap();
        assert_eq!(s.n, 50);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 50.0);
        assert!((s.mean - 25.5).abs() < 1e-9);
        assert_eq!(r.samples().len(), 50);
    }

    #[test]
    fn above_capacity_bounds_storage_and_keeps_exact_aggregates() {
        let mut r = Reservoir::new(64, 7);
        for i in 1..=10_000 {
            r.record(i as f64);
        }
        assert_eq!(r.samples().len(), 64, "storage bounded");
        let s = r.stats().unwrap();
        assert_eq!(s.n, 10_000, "count exact");
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 10_000.0);
        assert!((s.mean - 5000.5).abs() < 1e-9, "mean exact: {}", s.mean);
        // uniform subsample: the median estimate should land in the
        // middle half of the range with overwhelming probability
        assert!(s.p50 > 2_500.0 && s.p50 < 7_500.0, "p50 {}", s.p50);
    }

    #[test]
    fn deterministic_under_same_seed() {
        let feed = |seed| {
            let mut r = Reservoir::new(16, seed);
            for i in 0..1000 {
                r.record((i * 37 % 101) as f64);
            }
            r.samples().to_vec()
        };
        assert_eq!(feed(42), feed(42));
        assert_ne!(feed(42), feed(43), "different seeds diverge");
    }

    #[test]
    fn quantile_interpolates_and_clamps() {
        let mut r = Reservoir::new(16, 7);
        for x in [10.0, 20.0, 30.0, 40.0] {
            r.record(x);
        }
        assert_eq!(r.quantile(0.0), Some(10.0));
        assert_eq!(r.quantile(1.0), Some(40.0));
        assert_eq!(r.quantile(0.5), Some(25.0), "linear between ranks");
        assert_eq!(r.quantile(-3.0), Some(10.0), "clamped low");
        assert_eq!(r.quantile(9.0), Some(40.0), "clamped high");
        assert_eq!(Reservoir::new(4, 1).quantile(0.5), None);
    }

    /// Sorted-reference oracle: the textbook interpolated quantile over
    /// the full (sorted) stream.
    fn oracle_quantile(stream: &[f64], q: f64) -> f64 {
        let mut sorted = stream.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
        let (lo, frac) = (pos.floor() as usize, pos.fract());
        let hi = (lo + 1).min(sorted.len() - 1);
        sorted[lo] + (sorted[hi] - sorted[lo]) * frac
    }

    #[test]
    fn quantile_matches_sorted_oracle_below_capacity() {
        crate::util::propcheck::forall("reservoir quantile vs oracle", 128, |g| {
            let n = g.dim(64);
            let cap = n + g.usize(0, 32); // everything retained
            let stream: Vec<f64> =
                (0..n).map(|_| g.rng().f64() * 1e4 - 5e3).collect();
            let mut r = Reservoir::new(cap, 11);
            for &x in &stream {
                r.record(x);
            }
            for i in 0..=10 {
                let q = i as f64 / 10.0;
                let got = r.quantile(q).unwrap();
                let want = oracle_quantile(&stream, q);
                assert!(
                    (got - want).abs() <= 1e-9 * (1.0 + want.abs()),
                    "q={q}: got {got}, oracle {want}"
                );
            }
        });
    }

    #[test]
    fn quantile_is_monotone_and_bounded_above_capacity() {
        crate::util::propcheck::forall("reservoir quantile monotone", 64, |g| {
            let cap = g.dim(32);
            let n = cap + g.usize(1, 512);
            let mut r = Reservoir::new(cap, 3);
            for _ in 0..n {
                r.record(g.rng().f64() * 100.0);
            }
            let mut prev = f64::NEG_INFINITY;
            for i in 0..=20 {
                let v = r.quantile(i as f64 / 20.0).unwrap();
                assert!(v >= prev, "quantiles must be nondecreasing");
                assert!(v >= r.min() && v <= r.max(), "within exact bounds");
                prev = v;
            }
        });
    }

    #[test]
    fn merged_stats_matches_pooled_oracle_below_capacity() {
        crate::util::propcheck::forall("merged_stats vs pooled oracle", 64, |g| {
            let parts = g.usize(1, 5);
            let mut reservoirs = Vec::new();
            let mut pooled = Vec::new();
            for p in 0..parts {
                let n = g.dim(48);
                let mut r = Reservoir::new(64, p as u64);
                for _ in 0..n {
                    let x = g.rng().f64() * 1e3;
                    r.record(x);
                    pooled.push(x);
                }
                reservoirs.push(r);
            }
            let refs: Vec<&Reservoir> = reservoirs.iter().collect();
            let m = merged_stats(&refs).unwrap();
            let want = Stats::from_samples(&pooled);
            assert_eq!(m.n, want.n);
            assert!((m.mean - want.mean).abs() < 1e-9 * (1.0 + want.mean.abs()));
            assert_eq!(m.min, want.min);
            assert_eq!(m.max, want.max);
            // below capacity the pooled subsample IS the pooled stream,
            // so even the estimated percentiles agree with the oracle
            for (got, oracle) in
                [(m.p50, want.p50), (m.p95, want.p95), (m.p99, want.p99)]
            {
                assert_eq!(got, oracle, "pooled percentile must be exact");
            }
        });
    }

    #[test]
    fn merged_stats_pools_exactly_below_capacity() {
        let mut a = Reservoir::new(100, 1);
        let mut b = Reservoir::new(100, 2);
        for i in 1..=10 {
            a.record(i as f64);
            b.record((i + 10) as f64);
        }
        let m = merged_stats(&[&a, &b]).unwrap();
        assert_eq!(m.n, 20);
        assert_eq!(m.min, 1.0);
        assert_eq!(m.max, 20.0);
        assert!((m.mean - 10.5).abs() < 1e-9);
        assert!(merged_stats(&[]).is_none());
    }
}
