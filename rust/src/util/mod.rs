//! Small substrates the offline environment forces us to own: a PRNG,
//! a property-testing harness, report tables, and timing helpers.

pub mod aligned;
pub mod alloc;
pub mod propcheck;
pub mod reservoir;
pub mod rng;
pub mod table;
pub mod timing;

pub use rng::Rng;
pub use table::Table;

/// Ceiling division for scheduling/tiling math.
#[inline]
pub fn cdiv(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Round `a` up to the next multiple of `m` (NodePad-style capacity math).
#[inline]
pub fn round_up(a: usize, m: usize) -> usize {
    cdiv(a, m) * m
}

/// Human-readable byte count for logs and reports.
pub fn human_bytes(bytes: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Escape a string for embedding in a JSON string literal (the bench
/// harness emits machine-readable JSON by hand — serde is unavailable
/// offline). Control characters are not expected in bench labels.
pub fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Human-readable duration from microseconds.
pub fn human_us(us: f64) -> String {
    if us < 1e3 {
        format!("{us:.1} µs")
    } else if us < 1e6 {
        format!("{:.2} ms", us / 1e3)
    } else {
        format!("{:.3} s", us / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdiv_rounds_up() {
        assert_eq!(cdiv(10, 3), 4);
        assert_eq!(cdiv(9, 3), 3);
        assert_eq!(cdiv(1, 128), 1);
    }

    #[test]
    fn round_up_multiples() {
        assert_eq!(round_up(2708, 128), 2816);
        assert_eq!(round_up(128, 128), 128);
        assert_eq!(round_up(0, 128), 0);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert!(human_bytes(3 * 1024 * 1024).starts_with("3.00 MiB"));
    }

    #[test]
    fn human_us_scales() {
        assert_eq!(human_us(12.0), "12.0 µs");
        assert_eq!(human_us(1500.0), "1.50 ms");
        assert_eq!(human_us(2_000_000.0), "2.000 s");
    }

    #[test]
    fn json_escape_quotes_and_backslashes() {
        assert_eq!(json_escape(r#"a"b\c"#), r#"a\"b\\c"#);
        assert_eq!(json_escape("plain"), "plain");
    }
}
