//! Plain-text report tables — every paper figure/table harness prints
//! through this so EXPERIMENTS.md entries are copy-pasteable.

/// A simple aligned text table with a title and column headers.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from display-ables.
    pub fn rowd(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                w[i] = w[i].max(cell.len());
            }
        }
        w
    }

    /// Render as GitHub-flavored markdown (for EXPERIMENTS.md).
    pub fn markdown(&self) -> String {
        let w = self.widths();
        let mut out = format!("### {}\n\n", self.title);
        let fmt_row = |cells: &[String]| {
            let padded: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = w[i]))
                .collect();
            format!("| {} |", padded.join(" | "))
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        let dashes: Vec<String> = w.iter().map(|&w| "-".repeat(w)).collect();
        out.push_str(&format!("| {} |\n", dashes.join(" | ")));
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        println!("{}", self.markdown());
    }
}

/// Format a speedup factor the way the paper does ("2.7x").
pub fn speedup(baseline: f64, optimized: f64) -> String {
    if optimized <= 0.0 {
        return "inf".into();
    }
    format!("{:.2}x", baseline / optimized)
}

/// Format a percentage.
pub fn pct(frac: f64) -> String {
    format!("{:.1}%", frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("Demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "2".into()]);
        let md = t.markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| name   | value |"));
        assert!(md.contains("| longer | 2     |"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn speedup_formatting() {
        assert_eq!(speedup(10.0, 5.0), "2.00x");
        assert_eq!(speedup(3.0, 2.0), "1.50x");
        assert_eq!(speedup(1.0, 0.0), "inf");
    }

    #[test]
    fn pct_formatting() {
        assert_eq!(pct(0.55), "55.0%");
        assert_eq!(pct(0.991), "99.1%");
    }
}
