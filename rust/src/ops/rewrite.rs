//! GraNNite optimization passes as structural IR rewrites.
//!
//! The builders in [`super::build`] can emit any variant directly; these
//! passes exist because the *paper's* framework takes a deployed baseline
//! graph and transforms it (Fig. 6: "model optimization" happens between
//! the pre-trained model and the NPU blob). Every pass is verified against
//! the reference executor in tests: EffOp and GrAx3 (on non-negative
//! data) are exact; GrAx1 is an approximation with a provably tiny
//! post-softmax drift.
//!
//! Pass framework: a rewrite walks the op vec in topological order and
//! emits ops into a fresh graph through an id remap, optionally replacing
//! recognized patterns. Dead ops (e.g. the orphaned `Select` operands)
//! are dropped by a final liveness sweep.

use anyhow::{bail, Result};

use super::{Op, OpGraph, OpId, OpKind, Stage, NEG_MASK};
use crate::tensor::DType;

/// Remove ops whose value cannot reach any output (post-rewrite cleanup).
pub fn eliminate_dead(g: &OpGraph) -> OpGraph {
    let mut live = vec![false; g.ops.len()];
    let mut stack: Vec<OpId> = g.outputs.clone();
    while let Some(id) = stack.pop() {
        if !live[id] {
            live[id] = true;
            stack.extend_from_slice(&g.ops[id].inputs);
        }
    }
    // keep *named inputs* alive? No: an input no longer consumed should
    // disappear from the signature too (GrAx drops `edges` entirely).
    let mut remap = vec![usize::MAX; g.ops.len()];
    let mut out = OpGraph::new(g.name.clone());
    for (id, op) in g.ops.iter().enumerate() {
        if live[id] {
            let mut new_op = op.clone();
            new_op.inputs = op.inputs.iter().map(|&i| remap[i]).collect();
            remap[id] = out.push(new_op);
        }
    }
    out.outputs = g.outputs.iter().map(|&o| remap[o]).collect();
    out
}

/// EffOp (paper Fig. 12): replace `Select(mask, e, big_negative)` with
/// `e*mask + (1-mask)*NEG_MASK`, and monolithic `Softmax` with the
/// decomposed reduction form — moving the work from the DSP to the DPU.
pub fn effop(g: &OpGraph) -> Result<OpGraph> {
    let mut out = OpGraph::new(format!("{}+effop", g.name));
    let mut remap: Vec<OpId> = vec![usize::MAX; g.ops.len()];
    let mut changed = false;

    for (id, op) in g.ops.iter().enumerate() {
        let mapped: Vec<OpId> = op.inputs.iter().map(|&i| remap[i]).collect();
        let new_id = match &op.kind {
            OpKind::Select if is_neg_const(g, op.inputs[2]) => {
                changed = true;
                let mask = mapped[0];
                let e = mapped[1];
                let sh = &op.shape;
                let st = op.stage;
                let on = out.op(OpKind::Mul, &[e, mask], sh, st);
                let zero = out.op(OpKind::Scale(0.0), &[mask], sh, st);
                let ones = out.op(OpKind::AddConst(1.0), &[zero], sh, st);
                let comp = out.op(OpKind::Sub, &[ones, mask], sh, st);
                let off = out.op(OpKind::Scale(NEG_MASK), &[comp], sh, st);
                out.op(OpKind::Add, &[on, off], sh, st)
            }
            OpKind::Softmax => {
                changed = true;
                let x = mapped[0];
                let (n, st) = (op.shape[0], op.stage);
                let sh = &op.shape;
                let mx = out.op(OpKind::ReduceMaxRows, &[x], &[n, 1], st);
                let sub = out.op(OpKind::Sub, &[x, mx], sh, st);
                let ex = out.op(OpKind::Exp, &[sub], sh, st);
                let sm = out.op(OpKind::ReduceSumRows, &[ex], &[n, 1], st);
                let rc = out.op(OpKind::Reciprocal, &[sm], &[n, 1], st);
                out.op(OpKind::Mul, &[ex, rc], sh, st)
            }
            _ => out.push(Op { inputs: mapped, ..op.clone() }),
        };
        remap[id] = new_id;
    }
    if !changed {
        bail!("effop: no Select/Softmax patterns found in {}", g.name);
    }
    out.outputs = g.outputs.iter().map(|&o| remap[o]).collect();
    Ok(eliminate_dead(&out))
}

/// GrAx1 (paper Fig. 16): replace the *multiplicative* masking composite
/// `e*mask + (1-mask)*NEG` (EffOp's form) with a single additive-mask op
/// `e + neg_bias`, where `neg_bias` becomes a new graph input prepared on
/// the CPU. Also rewrites a baseline `Select` directly if present.
pub fn grax1(g: &OpGraph) -> Result<OpGraph> {
    // work on the EffOp form: find Add(Mul(e,mask), Scale(NEG, Sub(..)))
    let mut out = OpGraph::new(format!("{}+grax1", g.name));
    let mut remap: Vec<OpId> = vec![usize::MAX; g.ops.len()];
    let mut neg_bias_input: Option<OpId> = None;
    let mut changed = false;

    for (id, op) in g.ops.iter().enumerate() {
        let mapped: Vec<OpId> = op.inputs.iter().map(|&i| remap[i]).collect();
        let replaced = match &op.kind {
            OpKind::Add => match_mask_mul_add(g, op).map(|e_src| {
                let nb = *neg_bias_input.get_or_insert_with(|| {
                    out.input("neg_bias", &op.shape, DType::F32, Stage::Compute)
                });
                out.op(OpKind::Add, &[remap[e_src], nb], &op.shape, op.stage)
            }),
            OpKind::Select if is_neg_const(g, op.inputs[2]) => {
                let nb = *neg_bias_input.get_or_insert_with(|| {
                    out.input("neg_bias", &op.shape, DType::F32, Stage::Compute)
                });
                Some(out.op(OpKind::Add, &[mapped[1], nb], &op.shape, op.stage))
            }
            _ => None,
        };
        remap[id] = match replaced {
            Some(new_id) => {
                changed = true;
                new_id
            }
            None => out.push(Op { inputs: mapped, ..op.clone() }),
        };
    }
    if !changed {
        bail!("grax1: no masking pattern found in {}", g.name);
    }
    out.outputs = g.outputs.iter().map(|&o| remap[o]).collect();
    Ok(eliminate_dead(&out))
}

/// GrAx2 (paper Fig. 17): rewrite `Transpose(BroadcastCol(t))` — an n×n
/// data transpose — into `BroadcastRow(Transpose(t))`, transposing only
/// the (n,1) vector before broadcasting.
pub fn grax2(g: &OpGraph) -> Result<OpGraph> {
    let mut out = OpGraph::new(format!("{}+grax2", g.name));
    let mut remap: Vec<OpId> = vec![usize::MAX; g.ops.len()];
    let mut changed = false;

    for (id, op) in g.ops.iter().enumerate() {
        let mapped: Vec<OpId> = op.inputs.iter().map(|&i| remap[i]).collect();
        let new_id = match &op.kind {
            OpKind::Transpose
                if g.ops[op.inputs[0]].kind == OpKind::BroadcastCol
                    && op.shape.len() == 2
                    && op.shape[0] == op.shape[1] =>
            {
                changed = true;
                let bc = &g.ops[op.inputs[0]];
                let vec_src = remap[bc.inputs[0]]; // the (n,1) vector
                let n = op.shape[0];
                let st = op.stage;
                let tt = out.op(OpKind::Transpose, &[vec_src], &[1, n], st);
                out.op(OpKind::BroadcastRow, &[tt], &[n, n], st)
            }
            _ => out.push(Op { inputs: mapped, ..op.clone() }),
        };
        remap[id] = new_id;
    }
    if !changed {
        bail!("grax2: no Transpose(BroadcastCol) pattern in {}", g.name);
    }
    out.outputs = g.outputs.iter().map(|&o| remap[o]).collect();
    Ok(eliminate_dead(&out))
}

/// GrAx3 (paper Fig. 18): replace the sequential `NeighborGatherMax`
/// with `MaskedMaxPool` over a dense sampled-adjacency mask input.
pub fn grax3(g: &OpGraph) -> Result<OpGraph> {
    let mut out = OpGraph::new(format!("{}+grax3", g.name));
    let mut remap: Vec<OpId> = vec![usize::MAX; g.ops.len()];
    let mut mask_input: Option<OpId> = None;
    let mut changed = false;

    for (id, op) in g.ops.iter().enumerate() {
        let mapped: Vec<OpId> = op.inputs.iter().map(|&i| remap[i]).collect();
        let new_id = match &op.kind {
            OpKind::NeighborGatherMax => {
                changed = true;
                let n = op.shape[0];
                let mask = *mask_input.get_or_insert_with(|| {
                    out.input("mask", &[n, n], DType::F32, Stage::Compute)
                });
                out.op(OpKind::MaskedMaxPool, &[mask, mapped[1]], &op.shape, op.stage)
            }
            _ => out.push(Op { inputs: mapped, ..op.clone() }),
        };
        remap[id] = new_id;
    }
    if !changed {
        bail!("grax3: no NeighborGatherMax in {}", g.name);
    }
    out.outputs = g.outputs.iter().map(|&o| remap[o]).collect();
    Ok(eliminate_dead(&out))
}

/// True if op `id` computes a constant ≤ NEG_MASK (the −∞ stand-in fed to
/// baseline Select masking): matches `AddConst(NEG)(Scale(0)(…))`.
fn is_neg_const(g: &OpGraph, id: OpId) -> bool {
    match &g.ops[id].kind {
        OpKind::AddConst(c) if *c <= NEG_MASK => {
            matches!(g.ops[g.ops[id].inputs[0]].kind, OpKind::Scale(s) if s == 0.0)
        }
        _ => false,
    }
}

/// Match EffOp's masking composite rooted at an `Add`:
/// `Add(Mul(e, mask), Scale(NEG)(Sub(ones, mask)))` → returns the raw
/// (unmasked) score op `e`.
fn match_mask_mul_add(g: &OpGraph, add: &Op) -> Option<OpId> {
    if add.inputs.len() != 2 {
        return None;
    }
    let (lhs, rhs) = (&g.ops[add.inputs[0]], &g.ops[add.inputs[1]]);
    let mul = if lhs.kind == OpKind::Mul { lhs } else { return None };
    let scale_ok = matches!(rhs.kind, OpKind::Scale(s) if s <= NEG_MASK);
    if !scale_ok {
        return None;
    }
    let sub = &g.ops[rhs.inputs[0]];
    if sub.kind != OpKind::Sub {
        return None;
    }
    Some(mul.inputs[0]) // e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::ops::build::{gat, gcn_baseline, sage_max_baseline, GatVariant, GnnDims};
    use crate::ops::exec::{execute_mat, Bindings};
    use crate::tensor::{Mat, Tensor};
    use crate::util::Rng;

    fn dims() -> GnnDims {
        GnnDims { n: 14, m: 20, f: 10, hidden: 6, classes: 3, k: 4, layers: 2 }
    }

    fn test_graph() -> Graph {
        let mut rng = Rng::new(5);
        let edges: Vec<(u32, u32)> = (0..20)
            .map(|_| (rng.usize(14) as u32, rng.usize(14) as u32))
            .collect();
        Graph::new(14, &edges)
    }

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_fn(r, c, |_, _| (rng.f64() * 2.0 - 1.0) as f32)
    }

    /// Bindings for a GAT graph (whatever inputs it declares).
    fn gat_bindings(g: &OpGraph, graph: &Graph, d: GnnDims) -> Bindings {
        let mut rng = Rng::new(77);
        let x = rand_mat(&mut rng, d.n, d.f);
        let mut b = Bindings::new();
        let mut weights: std::collections::BTreeMap<&str, Mat> = Default::default();
        for l in 1..=2 {
            let (inw, outw) = if l == 1 { (d.f, d.hidden) } else { (d.hidden, d.classes) };
            weights.insert(if l == 1 { "w1" } else { "w2" }, rand_mat(&mut rng, inw, outw));
            weights.insert(if l == 1 { "a1_src" } else { "a2_src" }, rand_mat(&mut rng, outw, 1));
            weights.insert(if l == 1 { "a1_dst" } else { "a2_dst" }, rand_mat(&mut rng, outw, 1));
            weights.insert(if l == 1 { "b1" } else { "b2" }, rand_mat(&mut rng, 1, outw));
        }
        for (_, name) in g.inputs() {
            let t = match name {
                "edges" => {
                    let mut data = Vec::new();
                    for &(s, dd) in graph.edges() {
                        data.push(s as i32);
                        data.push(dd as i32);
                    }
                    // pad the edge input to the declared m with repeats
                    while data.len() < d.m * 2 {
                        data.push(graph.edges()[0].0 as i32);
                        data.push(graph.edges()[0].1 as i32);
                    }
                    data.truncate(d.m * 2);
                    Tensor::I32 { shape: vec![d.m, 2], data }
                }
                "x" => Tensor::from_mat(&x),
                "neg_bias" => Tensor::from_mat(&graph.neg_bias(d.n)),
                other => Tensor::from_mat(&weights[other]),
            };
            b.insert(name.to_string(), t);
        }
        b
    }

    #[test]
    fn effop_pass_is_exact_on_gat() {
        let d = dims();
        let graph = test_graph();
        // use the real edge count so padding doesn't duplicate edges
        let d = GnnDims { m: graph.num_edges(), ..d };
        let base = gat(d, GatVariant::Baseline);
        let rewritten = effop(&base).unwrap();
        rewritten.validate().unwrap();
        let b = gat_bindings(&base, &graph, d);
        let want = execute_mat(&base, &b).unwrap();
        let got = execute_mat(&rewritten, &b).unwrap();
        assert!(
            got.max_abs_diff(&want) < 1e-4,
            "effop drift {}",
            got.max_abs_diff(&want)
        );
        // and the DSP ops are gone
        let h = rewritten.op_histogram();
        assert!(h.get("Select").is_none());
        assert!(h.get("Softmax").is_none());
    }

    #[test]
    fn grax1_close_to_effop() {
        let d = dims();
        let graph = test_graph();
        let d = GnnDims { m: graph.num_edges(), ..d };
        let eff = effop(&gat(d, GatVariant::Baseline)).unwrap();
        let gx = grax1(&eff).unwrap();
        gx.validate().unwrap();
        // grax graph needs neg_bias instead of edges
        let b_eff = gat_bindings(&eff, &graph, d);
        let mut b_gx = gat_bindings(&gx, &graph, d);
        b_gx.insert(
            "neg_bias".into(),
            Tensor::from_mat(&graph.neg_bias(d.n)),
        );
        let want = execute_mat(&eff, &b_eff).unwrap();
        let got = execute_mat(&gx, &b_gx).unwrap();
        assert!(
            got.max_abs_diff(&want) < 1e-2,
            "grax1 drift {}",
            got.max_abs_diff(&want)
        );
        // BuildAdj is dead after the rewrite (mask no longer consumed)
        assert!(gx.op_histogram().get("BuildAdj").is_none());
    }

    #[test]
    fn grax2_preserves_numerics_exactly() {
        let d = dims();
        let graph = test_graph();
        let d = GnnDims { m: graph.num_edges(), ..d };
        let base = gat(d, GatVariant::Baseline);
        let rewritten = grax2(&base).unwrap();
        rewritten.validate().unwrap();
        let b = gat_bindings(&base, &graph, d);
        let want = execute_mat(&base, &b).unwrap();
        let got = execute_mat(&rewritten, &b).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-5);
        // no more n×n transposes
        let max_t = rewritten
            .ops
            .iter()
            .filter(|op| op.kind == OpKind::Transpose)
            .map(|op| op.num_elements())
            .max()
            .unwrap();
        assert_eq!(max_t, d.n);
    }

    #[test]
    fn grax3_exact_on_nonneg_features() {
        let d = dims();
        let graph = test_graph();
        let base = sage_max_baseline(d);
        let rewritten = grax3(&base).unwrap();
        rewritten.validate().unwrap();

        let mut rng = Rng::new(3);
        let idx_rows = graph.sampled_neighbors(d.k - 1, 7);
        let mut idx_data = Vec::new();
        for row in &idx_rows {
            for &j in row {
                idx_data.push(j as i32);
            }
        }
        let mut bind = Bindings::new();
        // non-negative features → GrAx3 exact (bag-of-words regime)
        bind.insert(
            "x".into(),
            Tensor::from_mat(&Mat::from_fn(d.n, d.f, |_, _| rng.f32())),
        );
        bind.insert(
            "nbr_idx".into(),
            Tensor::I32 { shape: vec![d.n, d.k], data: idx_data },
        );
        bind.insert(
            "mask".into(),
            Tensor::from_mat(&graph.sampled_adjacency(d.k - 1, 7, d.n)),
        );
        for l in 1..=2usize {
            let (inw, outw) = if l == 1 { (d.f, d.hidden) } else { (d.hidden, d.classes) };
            bind.insert(format!("w{l}_self"), Tensor::from_mat(&rand_mat(&mut rng, inw, outw)));
            bind.insert(format!("w{l}_neigh"), Tensor::from_mat(&rand_mat(&mut rng, inw, outw)));
            bind.insert(format!("b{l}"), Tensor::from_mat(&rand_mat(&mut rng, 1, outw)));
        }
        let want = execute_mat(&base, &bind).unwrap();
        let got = execute_mat(&rewritten, &bind).unwrap();
        // layer-2 features may be negative after combination, so GrAx3's
        // clipping can differ: compare predictions like the paper does.
        let agree = want
            .argmax_rows()
            .iter()
            .zip(got.argmax_rows())
            .filter(|(a, b)| **a == *b)
            .count();
        assert!(agree >= (d.n * 9) / 10, "agreement {agree}/{}", d.n);
    }

    #[test]
    fn passes_reject_graphs_without_patterns() {
        let g = gcn_baseline(dims());
        assert!(effop(&g).is_err()); // gcn baseline has no Select/Softmax
        assert!(grax3(&g).is_err());
        assert!(grax2(&g).is_err());
    }

    #[test]
    fn dead_elimination_drops_unused_inputs() {
        let mut g = OpGraph::new("dead");
        let x = g.input("x", &[2, 2], DType::F32, Stage::Compute);
        let _unused = g.input("unused", &[9, 9], DType::F32, Stage::Compute);
        let y = g.op(OpKind::Relu, &[x], &[2, 2], Stage::Compute);
        g.set_output(y);
        let clean = eliminate_dead(&g);
        assert_eq!(clean.len(), 2);
        assert_eq!(clean.inputs().len(), 1);
        clean.validate().unwrap();
    }
}
