//! Op-level IR — the OpenVINO-like layer GraNNite's techniques operate on.
//!
//! The paper's optimizations are (a) rewrites over an inference op graph
//! (EffOp, GrAx1-3, PreG folding) and (b) placement decisions over the
//! same graph (GraphSplit, baseline DPU/DSP mapping). This module gives
//! them a concrete substrate:
//!
//! - [`OpKind`]/[`Op`]/[`graph::OpGraph`]: a typed DAG with shapes, dtypes
//!   and pipeline-stage tags,
//! - [`build`]: builders emitting the baseline and optimized graphs for
//!   GCN / GAT / GraphSAGE,
//! - [`rewrite`]: the GraNNite passes,
//! - [`exec`]: an f32 reference executor used as the correctness oracle
//!   for every pass (mirroring `python/compile/kernels/ref.py` numerics),
//! - [`plan`]: compile-once execution plans (frozen topo order, buffer
//!   arena, fused elementwise chains, INT8 lowering) that
//!   [`crate::engine`] runs with zero steady-state allocations.

pub mod build;
pub mod exec;
pub mod graph;
pub mod plan;
pub mod rewrite;

pub use graph::{OpGraph, OpId};
pub use plan::ExecPlan;

/// GrAx1 additive mask constant (matches kernels/ref.py NEG_MASK).
pub const NEG_MASK: f32 = -1.0e9;

/// GAT LeakyReLU slope (matches kernels/ref.py LEAKY_SLOPE).
pub const LEAKY_SLOPE: f32 = 0.2;

/// Where an op sits in the GNN pipeline (paper Fig. 3) — Fig. 4's
/// breakdown is "preprocessing vs GNN compute" over this tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stage {
    /// Graph preprocessing: edge-list → adjacency/degree/norm structures.
    Preprocess,
    /// Aggregation + combination (the iterated GNN layers).
    Compute,
    /// Final decode (softmax/classification head).
    Decode,
}

impl std::fmt::Display for Stage {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Stage::Preprocess => write!(f, "preprocess"),
            Stage::Compute => write!(f, "compute"),
            Stage::Decode => write!(f, "decode"),
        }
    }
}

/// Which NPU engine class an op belongs to under the *default* (out-of-
/// the-box) mapping: data-parallel ops go to the DPU, control-heavy ops
/// to the DSP (paper Figs. 4–5). EffOp/GrAx change the graph so that the
/// same classification lands more work on the DPU.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Engine {
    Dpu,
    Dsp,
}

/// The op vocabulary. Dense ops carry no parameters beyond their shapes;
/// composite irregular ops (`ScatterAddEdges`, `NeighborGather*`, …)
/// stand for the fused control-heavy subgraphs the NPU compiler maps to
/// the DSP, and are the units Fig. 5 reports.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// Runtime input, bound by name at execution.
    Input,

    // ---- dense, DPU-class ----
    /// (m,k) @ (k,n) → (m,n).
    MatMul,
    /// Sparse × dense matmul: lhs is a CSR structure mask bound as a
    /// [`crate::tensor::Tensor::Csr`] input (the GraSp-native aggregation
    /// path, O(nnz·d) instead of O(m·k·n)). Same shape contract as
    /// MatMul; dense lhs bindings are accepted as the above-threshold
    /// fallback.
    SpMM,
    /// (m,n) → (n,m).
    Transpose,
    /// Elementwise add; rhs may be (1,n) (row broadcast) or (m,1) (col).
    Add,
    /// Elementwise subtract (same broadcast rules as Add).
    Sub,
    /// Elementwise multiply (same broadcast rules).
    Mul,
    /// Elementwise divide (same broadcast rules).
    Div,
    /// x * c.
    Scale(f32),
    /// x + c.
    AddConst(f32),
    /// max(x, 0).
    Relu,
    /// LeakyReLU with slope.
    LeakyRelu(f32),
    /// ELU (alpha = 1).
    Elu,
    /// e^x.
    Exp,
    /// √x.
    Sqrt,
    /// 1/√x.
    Rsqrt,
    /// 1/x — used to turn an (n,1) division into a cheap reciprocal plus
    /// a DPU broadcast-multiply (the EffOp softmax decomposition).
    Reciprocal,
    /// (m,1) → (m,n).
    BroadcastCol,
    /// (1,n) → (m,n).
    BroadcastRow,
    /// Row-wise sum: (m,n) → (m,1).
    ReduceSumRows,
    /// Row-wise max: (m,n) → (m,1).
    ReduceMaxRows,
    /// GrAx3: (mask (m,n), h (n,f)) → (m,f), out[i,j] = max_k mask[i,k]·h[k,j].
    MaskedMaxPool,

    // ---- control-heavy, DSP-class under the default mapping ----
    /// a > b → 1.0/0.0 elementwise.
    Greater,
    /// (cond, a, b) → cond ? a : b.
    Select,
    /// Row-wise numerically-stable softmax.
    Softmax,
    /// (edges (m,2)) → (n,1) degrees including self loop.
    DegreesFromEdges,
    /// (edges (m,2)) → (n,n) dense A + I.
    AdjacencyFromEdges,
    /// (edges (m,2), x (n,f)) → (n,f): Σ_{j∈N(i)} x_j + x_i.
    ScatterAddEdges,
    /// (idx (n,k), h (n,f)) → (n,f): max over gathered rows (sentinel n
    /// excluded; all-sentinel rows yield 0). The sequential DSP mapping
    /// of SAGE-max.
    NeighborGatherMax,
    /// Same gather, mean over valid slots.
    NeighborGatherMean,

    // ---- QuantGr ----
    /// Symmetric static quantization to int8 (value semantics: round +
    /// clamp; carried as f32 in the reference executor).
    Quantize { scale: f32 },
    /// INT8×INT8→INT32→FP32 MatMul with the two static scales.
    QMatMul { x_scale: f32, w_scale: f32 },
}

impl OpKind {
    /// Default engine placement (the out-of-the-box NPU mapping).
    pub fn default_engine(&self) -> Engine {
        match self {
            OpKind::Greater
            | OpKind::Select
            | OpKind::Softmax
            | OpKind::DegreesFromEdges
            | OpKind::AdjacencyFromEdges
            | OpKind::ScatterAddEdges
            | OpKind::NeighborGatherMax
            | OpKind::NeighborGatherMean
            | OpKind::Sqrt
            | OpKind::Rsqrt
            | OpKind::Reciprocal
            | OpKind::Div
            | OpKind::Elu => Engine::Dsp,
            _ => Engine::Dpu,
        }
    }

    /// Short mnemonic for tables/figures.
    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Input => "Input",
            OpKind::MatMul => "MatMul",
            OpKind::SpMM => "SpMM",
            OpKind::Transpose => "Transpose",
            OpKind::Add => "Add",
            OpKind::Sub => "Sub",
            OpKind::Mul => "Mul",
            OpKind::Div => "Div",
            OpKind::Scale(_) => "Scale",
            OpKind::AddConst(_) => "AddConst",
            OpKind::Relu => "Relu",
            OpKind::LeakyRelu(_) => "LeakyRelu",
            OpKind::Elu => "Elu",
            OpKind::Exp => "Exp",
            OpKind::Sqrt => "Sqrt",
            OpKind::Rsqrt => "Rsqrt",
            OpKind::Reciprocal => "Reciprocal",
            OpKind::BroadcastCol => "Broadcast",
            OpKind::BroadcastRow => "Broadcast",
            OpKind::ReduceSumRows => "ReduceSum",
            OpKind::ReduceMaxRows => "ReduceMax",
            OpKind::MaskedMaxPool => "MaxPool",
            OpKind::Greater => "Greater",
            OpKind::Select => "Select",
            OpKind::Softmax => "Softmax",
            OpKind::DegreesFromEdges => "Degrees",
            OpKind::AdjacencyFromEdges => "BuildAdj",
            OpKind::ScatterAddEdges => "Scatter",
            OpKind::NeighborGatherMax => "GatherMax",
            OpKind::NeighborGatherMean => "GatherMean",
            OpKind::Quantize { .. } => "Quantize",
            OpKind::QMatMul { .. } => "QMatMul",
        }
    }
}

/// One node of the op DAG.
#[derive(Debug, Clone, PartialEq)]
pub struct Op {
    pub kind: OpKind,
    /// Producer ids, in positional argument order.
    pub inputs: Vec<OpId>,
    /// Output shape (rank ≤ 2 throughout the GNN graphs).
    pub shape: Vec<usize>,
    /// Output dtype.
    pub dtype: crate::tensor::DType,
    /// Pipeline stage for Fig. 4-style breakdowns.
    pub stage: Stage,
    /// Debug/bind name ("x", "norm", "w1", …) — required for Input ops.
    pub name: String,
}

impl Op {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.num_elements() * self.dtype.size()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_placement_matches_paper_fig5() {
        // Fig. 5: Select/Greater/Softmax/Elu run on the DSP out of the box;
        // MatMul runs on the DPU.
        assert_eq!(OpKind::Select.default_engine(), Engine::Dsp);
        assert_eq!(OpKind::Greater.default_engine(), Engine::Dsp);
        assert_eq!(OpKind::Softmax.default_engine(), Engine::Dsp);
        assert_eq!(OpKind::Elu.default_engine(), Engine::Dsp);
        assert_eq!(OpKind::MatMul.default_engine(), Engine::Dpu);
        // SpMM is the GraSp zero-skip datapath on the same MAC grid
        assert_eq!(OpKind::SpMM.default_engine(), Engine::Dpu);
        assert_eq!(OpKind::Mul.default_engine(), Engine::Dpu);
        assert_eq!(OpKind::MaskedMaxPool.default_engine(), Engine::Dpu);
    }

    #[test]
    fn preg_targets_are_dsp_ops() {
        // PreG exists to keep sqrt/div off the NPU's DSP.
        assert_eq!(OpKind::Sqrt.default_engine(), Engine::Dsp);
        assert_eq!(OpKind::Rsqrt.default_engine(), Engine::Dsp);
        assert_eq!(OpKind::Div.default_engine(), Engine::Dsp);
    }
}
