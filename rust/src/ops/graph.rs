//! The op DAG: construction helpers, validation, topological order.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use super::{Op, OpKind, Stage};
use crate::tensor::DType;

/// Index of an op within its graph.
pub type OpId = usize;

/// A directed acyclic op graph. Ops are stored in insertion order, which
/// is always a valid topological order (inputs must exist at insert time).
#[derive(Debug, Clone, Default)]
pub struct OpGraph {
    pub ops: Vec<Op>,
    /// Ids of the graph outputs (usually one: the logits).
    pub outputs: Vec<OpId>,
    /// Human-readable graph name ("gcn_baseline", …).
    pub name: String,
}

impl OpGraph {
    pub fn new(name: impl Into<String>) -> OpGraph {
        OpGraph { ops: Vec::new(), outputs: Vec::new(), name: name.into() }
    }

    /// Add an op; panics if an input id is out of range (construction bug).
    pub fn push(&mut self, op: Op) -> OpId {
        for &i in &op.inputs {
            assert!(i < self.ops.len(), "op input {i} not yet defined");
        }
        self.ops.push(op);
        self.ops.len() - 1
    }

    /// Declare a named runtime input.
    pub fn input(&mut self, name: &str, shape: &[usize], dtype: DType,
                 stage: Stage) -> OpId {
        self.push(Op {
            kind: OpKind::Input,
            inputs: vec![],
            shape: shape.to_vec(),
            dtype,
            stage,
            name: name.to_string(),
        })
    }

    /// Add a non-input op with an inferred f32 dtype.
    pub fn op(&mut self, kind: OpKind, inputs: &[OpId], shape: &[usize],
              stage: Stage) -> OpId {
        self.push(Op {
            kind,
            inputs: inputs.to_vec(),
            shape: shape.to_vec(),
            dtype: DType::F32,
            stage,
            name: String::new(),
        })
    }

    pub fn set_output(&mut self, id: OpId) {
        self.outputs = vec![id];
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Ids in topological order (= insertion order by construction).
    pub fn topo_order(&self) -> impl Iterator<Item = OpId> + '_ {
        0..self.ops.len()
    }

    /// Named inputs in declaration order.
    pub fn inputs(&self) -> Vec<(OpId, &str)> {
        self.ops
            .iter()
            .enumerate()
            .filter(|(_, op)| op.kind == OpKind::Input)
            .map(|(i, op)| (i, op.name.as_str()))
            .collect()
    }

    /// Consumers of each op (for liveness / rewrite bookkeeping).
    pub fn consumers(&self) -> Vec<Vec<OpId>> {
        let mut out = vec![Vec::new(); self.ops.len()];
        for (id, op) in self.ops.iter().enumerate() {
            for &src in &op.inputs {
                out[src].push(id);
            }
        }
        out
    }

    /// Count ops by mnemonic (Fig. 5 rows).
    pub fn op_histogram(&self) -> BTreeMap<&'static str, usize> {
        let mut h = BTreeMap::new();
        for op in &self.ops {
            if op.kind != OpKind::Input {
                *h.entry(op.kind.name()).or_insert(0) += 1;
            }
        }
        h
    }

    /// Structural validation: shapes consistent with op semantics.
    /// Builders and rewrites are both checked by this in tests.
    pub fn validate(&self) -> Result<()> {
        if self.outputs.is_empty() {
            bail!("{}: no outputs declared", self.name);
        }
        for (id, op) in self.ops.iter().enumerate() {
            let fail = |msg: String| -> Result<()> {
                bail!("{} op#{id} {}: {msg}", self.name, op.kind.name())
            };
            let in_shape =
                |k: usize| -> &[usize] { &self.ops[op.inputs[k]].shape };
            match &op.kind {
                OpKind::Input => {
                    if op.name.is_empty() {
                        return fail("unnamed input".into());
                    }
                }
                OpKind::MatMul | OpKind::SpMM | OpKind::QMatMul { .. } => {
                    let (a, b) = (in_shape(0), in_shape(1));
                    if a.len() != 2 || b.len() != 2 || a[1] != b[0] {
                        return fail(format!("bad matmul {a:?} @ {b:?}"));
                    }
                    if op.shape != vec![a[0], b[1]] {
                        return fail(format!(
                            "output {:?} != {:?}",
                            op.shape,
                            [a[0], b[1]]
                        ));
                    }
                }
                OpKind::Transpose => {
                    let a = in_shape(0);
                    if op.shape != vec![a[1], a[0]] {
                        return fail(format!("transpose {a:?} → {:?}", op.shape));
                    }
                }
                OpKind::Add | OpKind::Sub | OpKind::Mul | OpKind::Div => {
                    let (a, b) = (in_shape(0), in_shape(1));
                    let ok = a == b
                        || (b.len() == 2 && b[0] == 1 && b[1] == a[1])
                        || (b.len() == 2 && b[1] == 1 && b[0] == a[0]);
                    if !ok {
                        return fail(format!("bad broadcast {a:?} vs {b:?}"));
                    }
                    if op.shape != a {
                        return fail("output must match lhs".into());
                    }
                }
                OpKind::BroadcastCol => {
                    let a = in_shape(0);
                    if a[1] != 1 || op.shape[0] != a[0] {
                        return fail(format!("broadcast-col {a:?} → {:?}", op.shape));
                    }
                }
                OpKind::BroadcastRow => {
                    let a = in_shape(0);
                    if a[0] != 1 || op.shape[1] != a[1] {
                        return fail(format!("broadcast-row {a:?} → {:?}", op.shape));
                    }
                }
                OpKind::ReduceSumRows | OpKind::ReduceMaxRows => {
                    let a = in_shape(0);
                    if op.shape != vec![a[0], 1] {
                        return fail(format!("reduce {a:?} → {:?}", op.shape));
                    }
                }
                OpKind::Softmax => {
                    if op.shape != in_shape(0) {
                        return fail("softmax shape change".into());
                    }
                }
                OpKind::Select => {
                    if op.inputs.len() != 3 {
                        return fail("select needs cond,a,b".into());
                    }
                }
                OpKind::MaskedMaxPool => {
                    let (m, h) = (in_shape(0), in_shape(1));
                    if m[1] != h[0] || op.shape != vec![m[0], h[1]] {
                        return fail(format!("maxpool {m:?} x {h:?} → {:?}", op.shape));
                    }
                }
                OpKind::NeighborGatherMax | OpKind::NeighborGatherMean => {
                    let (idx, h) = (in_shape(0), in_shape(1));
                    if idx[0] != h[0] || op.shape != vec![h[0], h[1]] {
                        return fail(format!("gather {idx:?} x {h:?} → {:?}", op.shape));
                    }
                }
                _ => {}
            }
        }
        for &o in &self.outputs {
            if o >= self.ops.len() {
                bail!("{}: output id {o} out of range", self.name);
            }
        }
        Ok(())
    }

    /// Total MAC count of dense matmuls (roofline math for DESIGN.md §8).
    /// `SpMM` is excluded: its MAC count is O(nnz·d), a property of the
    /// bound operand, not of the graph shapes — [`crate::npu::cost`]
    /// prices it from the mask density instead.
    pub fn matmul_macs(&self) -> usize {
        self.ops
            .iter()
            .filter_map(|op| match op.kind {
                OpKind::MatMul | OpKind::QMatMul { .. } => {
                    let k = self.ops[op.inputs[0]].shape[1];
                    Some(op.num_elements() * k)
                }
                _ => None,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;

    fn tiny() -> OpGraph {
        let mut g = OpGraph::new("tiny");
        let x = g.input("x", &[4, 3], DType::F32, Stage::Compute);
        let w = g.input("w", &[3, 2], DType::F32, Stage::Compute);
        let y = g.op(OpKind::MatMul, &[x, w], &[4, 2], Stage::Compute);
        g.set_output(y);
        g
    }

    #[test]
    fn valid_graph_passes() {
        tiny().validate().unwrap();
    }

    #[test]
    fn inputs_enumerated_in_order() {
        let g = tiny();
        let names: Vec<&str> = g.inputs().into_iter().map(|(_, n)| n).collect();
        assert_eq!(names, vec!["x", "w"]);
    }

    #[test]
    fn bad_matmul_rejected() {
        let mut g = OpGraph::new("bad");
        let x = g.input("x", &[4, 3], DType::F32, Stage::Compute);
        let w = g.input("w", &[5, 2], DType::F32, Stage::Compute);
        let y = g.op(OpKind::MatMul, &[x, w], &[4, 2], Stage::Compute);
        g.set_output(y);
        let err = g.validate().unwrap_err().to_string();
        assert!(err.contains("matmul"), "{err}");
    }

    #[test]
    fn missing_output_rejected() {
        let mut g = OpGraph::new("noout");
        g.input("x", &[1, 1], DType::F32, Stage::Compute);
        assert!(g.validate().is_err());
    }

    #[test]
    #[should_panic(expected = "not yet defined")]
    fn forward_reference_panics() {
        let mut g = OpGraph::new("fwd");
        g.op(OpKind::Relu, &[3], &[1, 1], Stage::Compute);
    }

    #[test]
    fn consumers_tracked() {
        let g = tiny();
        let cons = g.consumers();
        assert_eq!(cons[0], vec![2]); // x feeds the matmul
        assert_eq!(cons[2], Vec::<usize>::new());
    }

    #[test]
    fn histogram_skips_inputs() {
        let h = tiny().op_histogram();
        assert_eq!(h.get("MatMul"), Some(&1));
        assert_eq!(h.get("Input"), None);
    }

    #[test]
    fn matmul_macs_counted() {
        assert_eq!(tiny().matmul_macs(), 4 * 2 * 3);
    }

    #[test]
    fn broadcast_validation() {
        let mut g = OpGraph::new("bc");
        let x = g.input("x", &[4, 3], DType::F32, Stage::Compute);
        let b = g.input("b", &[1, 3], DType::F32, Stage::Compute);
        let y = g.op(OpKind::Add, &[x, b], &[4, 3], Stage::Compute);
        g.set_output(y);
        g.validate().unwrap();

        let mut bad = OpGraph::new("bc2");
        let x = bad.input("x", &[4, 3], DType::F32, Stage::Compute);
        let b = bad.input("b", &[1, 2], DType::F32, Stage::Compute);
        let y = bad.op(OpKind::Add, &[x, b], &[4, 3], Stage::Compute);
        bad.set_output(y);
        assert!(bad.validate().is_err());
    }
}
