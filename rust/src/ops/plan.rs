//! Compile-once execution plans — the op-graph analogue of the paper's
//! Step-2 "eliminate per-inference overhead" techniques.
//!
//! [`crate::ops::exec`] interprets the graph on every call: it re-walks
//! the topo order, resolves inputs through a map, clones every operand
//! into a fresh `Mat`, and allocates every intermediate. [`ExecPlan`]
//! does all of that **once**:
//!
//! - the topological order is frozen into a flat step list,
//! - shapes are checked/folded ahead of time ([`OpGraph::validate`] plus
//!   rank normalization),
//! - a **liveness analysis** assigns every intermediate to a slab of a
//!   reusable buffer arena (two tensors whose live ranges do not overlap
//!   share one slab),
//! - runs of elementwise ops are folded into **fused chains** — a single
//!   streaming loop per chain, no intermediate materialization. What
//!   fuses is decided by [`crate::npu::sim::is_fusible`], the *same*
//!   predicate the NPU simulator's memory model uses, so the cost model
//!   and the real engine agree on which tensors never hit "DRAM",
//! - `Quantize` ops feeding only `QMatMul` lhs operands are lowered to
//!   **real INT8**: their output lives in an `i8` arena slab and the
//!   consuming matmul runs an i8×i8→i32 kernel (QuantGr's datapath)
//!   instead of the rounded-f32 emulation of the reference executor,
//! - `SpMM` sparse operands are recognized as **sparse inputs**: they
//!   bind indptr/indices/values ([`crate::tensor::Tensor::Csr`]) instead
//!   of n² floats, never occupy an arena slab, and the compile step
//!   verifies no dense consumer aliases them — so a sparse plan's
//!   steady-state memory is `arena_bytes()` + O(nnz), with no n×n slab
//!   anywhere.
//!
//! The plan itself is immutable and shareable ([`std::sync::Arc`]); the
//! mutable part (arena buffers, cached INT8 weights) lives in
//! [`crate::engine::PlanInstance`], which executes the plan with zero
//! steady-state allocations. `ops::exec` remains the correctness oracle:
//! every plan is property-tested against it (rust/tests/plan_equivalence.rs).

use anyhow::{bail, Result};

use super::{OpGraph, OpId, OpKind};
use crate::npu::sim::is_fusible;
use crate::tensor::{CsrMat, DType, DensityHint, Mat};

/// Sentinel for "no arena slot" (inputs, fused interiors, i8 outputs).
pub const NO_SLOT: usize = usize::MAX;

/// SIMD dispatch mode for the engine's microkernels. `Auto` and `On`
/// both select the register-blocked kernels today (they are
/// bit-comparable with the scalar path, so there is no correctness
/// reason to hold back); `Off` forces the scalar fallback — the oracle
/// configuration, and an escape hatch for targets where the blocked
/// kernels mis-tune.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimdMode {
    /// Engine decides (currently: SIMD on).
    #[default]
    Auto,
    /// Force the register-blocked kernels.
    On,
    /// Force the scalar fallback kernels.
    Off,
}

impl SimdMode {
    /// Whether the register-blocked kernels are dispatched.
    #[inline]
    pub fn enabled(self) -> bool {
        !matches!(self, SimdMode::Off)
    }

    /// Parse a spec-file value (`auto|on|off`).
    pub fn parse(s: &str) -> Result<SimdMode> {
        match s {
            "auto" => Ok(SimdMode::Auto),
            "on" => Ok(SimdMode::On),
            "off" => Ok(SimdMode::Off),
            other => bail!(
                "kernels.simd must be \"auto\", \"on\" or \"off\", got {other:?} \
                 — \"off\" is the scalar oracle path, \"auto\"/\"on\" dispatch \
                 the register-blocked kernels"
            ),
        }
    }

    /// Canonical spec-file spelling.
    pub fn name(self) -> &'static str {
        match self {
            SimdMode::Auto => "auto",
            SimdMode::On => "on",
            SimdMode::Off => "off",
        }
    }
}

/// CacheG-style node-reordering mode, applied once at plan-compile time
/// through [`Reordering`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReorderMode {
    /// Keep original node ids (identity).
    #[default]
    None,
    /// Stable degree-descending order — hubs first, pairs with
    /// nnz-balanced lane dispatch.
    Degree,
    /// Reverse Cuthill–McKee — bandwidth reduction, near-sequential
    /// neighbor gathers.
    Rcm,
}

impl ReorderMode {
    /// Parse a spec-file value (`none|degree|rcm`).
    pub fn parse(s: &str) -> Result<ReorderMode> {
        match s {
            "none" => Ok(ReorderMode::None),
            "degree" => Ok(ReorderMode::Degree),
            "rcm" => Ok(ReorderMode::Rcm),
            other => bail!(
                "kernels.reorder must be \"none\", \"degree\" or \"rcm\", got \
                 {other:?} — \"degree\" sorts hubs first for lane balance, \
                 \"rcm\" minimizes bandwidth for cache locality"
            ),
        }
    }

    /// Canonical spec-file spelling.
    pub fn name(self) -> &'static str {
        match self {
            ReorderMode::None => "none",
            ReorderMode::Degree => "degree",
            ReorderMode::Rcm => "rcm",
        }
    }
}

/// Kernel-layer knobs a plan is compiled with — carried on [`ExecPlan`]
/// so every runner of that plan (engine instances, incremental tiles)
/// dispatches identically. The serving layer lowers a validated
/// `[kernels]` spec section into one of these.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelConfig {
    /// SIMD microkernel dispatch.
    pub simd: SimdMode,
    /// Node-reordering pass (consumed by callers that own the bindings;
    /// see [`Reordering`]).
    pub reorder: ReorderMode,
    /// Chunks-per-lane granularity of the nnz-balanced SpMM dispenser.
    pub degree_bins: usize,
}

impl Default for KernelConfig {
    fn default() -> Self {
        KernelConfig {
            simd: SimdMode::Auto,
            reorder: ReorderMode::None,
            degree_bins: crate::engine::kernels::DEGREE_BINS_DEFAULT,
        }
    }
}

/// A CacheG-style stable node relabeling, computed **once** from the
/// aggregation mask's structure and applied as a pure permutation:
/// callers permute the CSR operand and every node-indexed binding before
/// running, and apply the inverse to served outputs — numerics are
/// untouched (each output row is the same dot products, just computed at
/// a different row index), so reordered runs match unordered ones
/// bitwise after [`Reordering::restore_rows`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Reordering {
    /// `perm[new] = old`: position `new` holds original node `old`.
    pub perm: Vec<u32>,
    /// `inv[old] = new`.
    pub inv: Vec<u32>,
}

impl Reordering {
    /// Compute the ordering `mode` prescribes over a CSR adjacency.
    /// Returns `None` for [`ReorderMode::None`] so callers skip the
    /// permutation work entirely.
    pub fn compute(mode: ReorderMode, indptr: &[u32], indices: &[u32]) -> Option<Reordering> {
        let perm = match mode {
            ReorderMode::None => return None,
            ReorderMode::Degree => crate::graph::csr::degree_order(indptr),
            ReorderMode::Rcm => crate::graph::csr::rcm_order(indptr, indices),
        };
        let inv = crate::graph::csr::inverse_permutation(&perm);
        Some(Reordering { perm, inv })
    }

    /// Number of nodes the permutation covers.
    pub fn len(&self) -> usize {
        self.perm.len()
    }

    /// True for the zero-node permutation.
    pub fn is_empty(&self) -> bool {
        self.perm.is_empty()
    }

    /// Symmetric relabel of a square CSR operand: row `new` is original
    /// row `perm[new]` with column ids mapped through `inv` and re-sorted
    /// (sorted rows are what keeps SpMM bit-comparable to the dense
    /// zero-skip kernel).
    pub fn permute_csr(&self, m: &CsrMat) -> CsrMat {
        assert_eq!(m.rows, m.cols, "node reordering needs a square operand");
        assert_eq!(m.rows, self.len(), "permutation covers every node");
        let mut indptr = Vec::with_capacity(m.rows + 1);
        let mut indices = Vec::with_capacity(m.indices.len());
        let mut values = Vec::with_capacity(m.values.len());
        let mut row: Vec<(u32, f32)> = Vec::new();
        indptr.push(0u32);
        for &old in &self.perm {
            let (cols, vals) = m.row_entries(old as usize);
            row.clear();
            row.extend(
                cols.iter()
                    .zip(vals)
                    .map(|(&c, &v)| (self.inv[c as usize], v)),
            );
            row.sort_unstable_by_key(|&(c, _)| c);
            for &(c, v) in &row {
                indices.push(c);
                values.push(v);
            }
            indptr.push(indices.len() as u32);
        }
        CsrMat { rows: m.rows, cols: m.cols, indptr, indices, values }
    }

    /// Row permutation of a node-indexed matrix: `out.row(new) =
    /// m.row(perm[new])`. Applied to feature bindings before a reordered
    /// run.
    pub fn permute_rows(&self, m: &Mat) -> Mat {
        assert_eq!(m.rows, self.len(), "permutation covers every row");
        Mat::from_fn(m.rows, m.cols, |i, j| m[(self.perm[i] as usize, j)])
    }

    /// Inverse row permutation: `out.row(old) = m.row(inv[old])`.
    /// Applied to a reordered run's output so callers see original node
    /// order; `restore_rows(permute_rows(x)) == x` exactly.
    pub fn restore_rows(&self, m: &Mat) -> Mat {
        assert_eq!(m.rows, self.len(), "permutation covers every row");
        Mat::from_fn(m.rows, m.cols, |i, j| m[(self.inv[i] as usize, j)])
    }
}

/// Position transform from a chain's output coordinates to an upstream
/// operand's coordinates: broadcasts later in the chain pin the earlier
/// row (`zero_i`) or column (`zero_j`) index to 0.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PosT {
    pub zero_i: bool,
    pub zero_j: bool,
}

/// A chain operand (its head input or one binary step's second operand):
/// the producing op plus the position transform accumulated through any
/// later broadcast steps. Rows/cols are the producer's normalized shape.
#[derive(Debug, Clone)]
pub struct ChainSrc {
    pub op: OpId,
    pub rows: usize,
    pub cols: usize,
    pub pos: PosT,
}

/// One scalar stage of a fused chain. Binary stages carry an index into
/// [`Chain::aux`]; `Broadcast` stages are pure index remaps folded into
/// the [`PosT`] transforms at compile time.
#[derive(Debug, Clone, Copy)]
pub enum FusedOp {
    Scale(f32),
    AddConst(f32),
    Relu,
    LeakyRelu(f32),
    Exp,
    Quantize(f32),
    Broadcast,
    Add(u32),
    Sub(u32),
    Mul(u32),
}

/// A maximal run of fusible elementwise ops executed as one streaming
/// loop over the tail op's elements. Interior ops never materialize.
#[derive(Debug, Clone)]
pub struct Chain {
    /// Member op ids in execution order (tail last).
    pub ops: Vec<OpId>,
    /// Input 0 of the first op.
    pub head: ChainSrc,
    /// Second operands of binary stages, in stage order.
    pub aux: Vec<ChainSrc>,
    /// One stage per member op.
    pub steps: Vec<FusedOp>,
    /// Output geometry (the tail op's normalized shape).
    pub rows: usize,
    pub cols: usize,
}

/// How a plan step executes.
#[derive(Debug, Clone)]
pub enum StepKind {
    /// Fused elementwise chain (length ≥ 1).
    Chain(Chain),
    /// `Quantize` lowered to a real i8 arena slab (all consumers are
    /// QMatMul lhs operands).
    QuantizeI8 { scale: f32 },
    /// Any other op, dispatched to a dedicated kernel.
    Kernel,
}

/// One frozen execution step; `op` is the id whose value it produces
/// (the tail op for fused chains).
#[derive(Debug, Clone)]
pub struct PlanStep {
    pub op: OpId,
    pub kind: StepKind,
}

/// A compiled, immutable execution plan. See the module docs.
#[derive(Debug, Clone)]
pub struct ExecPlan {
    pub graph: OpGraph,
    pub steps: Vec<PlanStep>,
    /// Op id → f32 arena slot ([`NO_SLOT`] for inputs/interiors/i8 ops).
    pub slot: Vec<usize>,
    /// Op id → i8 arena slot (only `QuantizeI8` outputs).
    pub i8_slot: Vec<usize>,
    /// Element capacity of each f32 slab.
    pub slab_elems: Vec<usize>,
    /// Element capacity of each i8 slab.
    pub i8_slab_elems: Vec<usize>,
    /// Op id → true for Input ops bound as `SpMM` sparse operands (CSR
    /// bindings; no dense slab, no f32 resolution).
    pub sparse_input: Vec<bool>,
    /// Ops folded away as fused-chain interiors.
    pub fused_away: usize,
    /// Kernel-layer knobs this plan was compiled with.
    pub kernels: KernelConfig,
    /// Op id → lhs density class for `MatMul` steps: computed activations
    /// are dense by construction ([`DensityHint::NoSkip`], no per-call
    /// probe); graph-input operands stay [`DensityHint::Sample`].
    pub density_hint: Vec<DensityHint>,
}

/// Normalized (rows, cols) of an op's output; rank-1 shapes are row
/// vectors, rank-0 are scalars (matches `Tensor::to_mat`).
pub fn rc(shape: &[usize]) -> Result<(usize, usize)> {
    match shape.len() {
        2 => Ok((shape[0], shape[1])),
        1 => Ok((1, shape[0])),
        0 => Ok((1, 1)),
        r => bail!("rank-{r} tensors unsupported by the planned engine"),
    }
}

impl ExecPlan {
    /// Compile `g` into a plan with default kernel knobs. Fails on graphs
    /// the engine cannot run steady-state (unvalidated shapes, rank > 2,
    /// integer inputs that are not graph inputs, outputs that are raw
    /// inputs).
    pub fn compile(g: &OpGraph) -> Result<ExecPlan> {
        ExecPlan::compile_with(g, KernelConfig::default())
    }

    /// [`ExecPlan::compile`] with explicit kernel-layer knobs — the entry
    /// point the serving layer's `[kernels]` spec section lowers into.
    pub fn compile_with(g: &OpGraph, kernels: KernelConfig) -> Result<ExecPlan> {
        g.validate()?;
        let n = g.ops.len();
        for op in &g.ops {
            rc(&op.shape)?;
        }
        for &o in &g.outputs {
            if g.ops[o].kind == OpKind::Input {
                bail!("{}: plan output #{o} is a raw input", g.name);
            }
        }
        // Integer-consuming kernels read their index tensor straight from
        // the bindings; a computed index tensor has no i32 arena.
        for (id, op) in g.ops.iter().enumerate() {
            let idx_input = match op.kind {
                OpKind::DegreesFromEdges
                | OpKind::AdjacencyFromEdges
                | OpKind::ScatterAddEdges
                | OpKind::NeighborGatherMax
                | OpKind::NeighborGatherMean => Some(op.inputs[0]),
                _ => None,
            };
            if let Some(src) = idx_input {
                if g.ops[src].kind != OpKind::Input {
                    bail!("{} op#{id}: computed index tensors unsupported", g.name);
                }
            }
        }
        // SpMM sparse operands resolve straight from the bindings (CSR
        // arrays, no arena slab): the lhs must be a graph input, and a
        // CSR-bound input cannot double as a dense operand elsewhere.
        let mut sparse_input = vec![false; n];
        for (id, op) in g.ops.iter().enumerate() {
            if op.kind == OpKind::SpMM {
                let src = op.inputs[0];
                if g.ops[src].kind != OpKind::Input {
                    bail!(
                        "{} op#{id}: computed sparse operands unsupported \
                         (SpMM lhs must be a graph input)",
                        g.name
                    );
                }
                sparse_input[src] = true;
            }
        }
        for (id, op) in g.ops.iter().enumerate() {
            if op.kind == OpKind::SpMM {
                continue;
            }
            for (pos, &src) in op.inputs.iter().enumerate() {
                if sparse_input[src] {
                    bail!(
                        "{} op#{id} {}: input #{pos} is an SpMM sparse \
                         operand and cannot feed a dense consumer",
                        g.name,
                        op.kind.name()
                    );
                }
            }
        }
        for (id, op) in g.ops.iter().enumerate() {
            if op.kind == OpKind::SpMM && sparse_input[op.inputs[1]] {
                bail!(
                    "{} op#{id}: SpMM rhs must be dense, but its input is a \
                     sparse operand",
                    g.name
                );
            }
        }

        let consumers = g.consumers();
        let is_output = |id: OpId| g.outputs.contains(&id);

        // --- INT8 lowering: Quantize ops consumed only as QMatMul lhs ---
        let mut quant_i8 = vec![false; n];
        for (id, op) in g.ops.iter().enumerate() {
            if let OpKind::Quantize { .. } = op.kind {
                let cs = &consumers[id];
                let all_qmm_lhs = !cs.is_empty()
                    && cs.iter().all(|&c| {
                        matches!(g.ops[c].kind, OpKind::QMatMul { .. })
                            && g.ops[c].inputs[0] == id
                            && g.ops[c].inputs.iter().filter(|&&x| x == id).count() == 1
                    });
                if all_qmm_lhs && !is_output(id) {
                    quant_i8[id] = true;
                }
            }
        }

        // --- fusion chains (mirror npu::sim::is_fusible) ---
        let chainable =
            |id: OpId| is_fusible(&g.ops[id].kind) && !quant_i8[id];
        // link[a] = Some(b): a's value streams straight into b (b is a's
        // single consumer, reads it exactly once, as input 0)
        let mut link: Vec<Option<OpId>> = vec![None; n];
        let mut prev: Vec<Option<OpId>> = vec![None; n];
        for id in 0..n {
            if !chainable(id) || is_output(id) {
                continue;
            }
            if consumers[id].len() != 1 {
                continue;
            }
            let b = consumers[id][0];
            if chainable(b) && g.ops[b].inputs.first() == Some(&id) {
                link[id] = Some(b);
                prev[b] = Some(id);
            }
        }
        let interior = |id: OpId| link[id].is_some();

        // rep[id]: the step at which id's value is produced (chain tail
        // for interiors, itself otherwise)
        let mut rep: Vec<OpId> = (0..n).collect();
        for id in 0..n {
            if chainable(id) {
                let mut t = id;
                while let Some(nx) = link[t] {
                    t = nx;
                }
                rep[id] = t;
            }
        }

        // --- liveness: last step that reads each op's value ---
        let mut last_use: Vec<usize> = (0..n).collect();
        for (id, op) in g.ops.iter().enumerate() {
            for &src in &op.inputs {
                last_use[src] = last_use[src].max(rep[id]);
            }
        }
        for &o in &g.outputs {
            last_use[o] = usize::MAX;
        }
        let mut frees_at: Vec<Vec<OpId>> = vec![Vec::new(); n];
        for r in 0..n {
            if last_use[r] != usize::MAX && g.ops[r].kind != OpKind::Input {
                frees_at[last_use[r]].push(r);
            }
        }

        // --- arena slot assignment + step list ---
        let mut slot = vec![NO_SLOT; n];
        let mut i8_slot = vec![NO_SLOT; n];
        let mut slab_elems: Vec<usize> = Vec::new();
        let mut i8_slab_elems: Vec<usize> = Vec::new();
        let mut free_f32: Vec<usize> = Vec::new();
        let mut free_i8: Vec<usize> = Vec::new();
        let mut steps: Vec<PlanStep> = Vec::new();
        let mut fused_away = 0usize;

        fn acquire(free: &mut Vec<usize>, sizes: &mut Vec<usize>, need: usize) -> usize {
            // best fit among free slabs
            let mut best: Option<usize> = None;
            for (k, &s) in free.iter().enumerate() {
                if sizes[s] >= need {
                    let better = match best {
                        None => true,
                        Some(kb) => sizes[s] < sizes[free[kb]],
                    };
                    if better {
                        best = Some(k);
                    }
                }
            }
            if let Some(k) = best {
                return free.swap_remove(k);
            }
            // otherwise grow the largest free slab rather than adding one
            if !free.is_empty() {
                let mut kb = 0;
                for k in 1..free.len() {
                    if sizes[free[k]] > sizes[free[kb]] {
                        kb = k;
                    }
                }
                let s = free.swap_remove(kb);
                if sizes[s] < need {
                    sizes[s] = need;
                }
                return s;
            }
            sizes.push(need);
            sizes.len() - 1
        }

        for id in 0..n {
            let op = &g.ops[id];
            if op.kind == OpKind::Input {
                continue;
            }
            if interior(id) {
                fused_away += 1;
            } else {
                let (rows, cols) = rc(&op.shape)?;
                let need = rows * cols;
                if quant_i8[id] {
                    i8_slot[id] = acquire(&mut free_i8, &mut i8_slab_elems, need);
                    let scale = match op.kind {
                        OpKind::Quantize { scale } => scale,
                        _ => unreachable!(),
                    };
                    steps.push(PlanStep { op: id, kind: StepKind::QuantizeI8 { scale } });
                } else {
                    slot[id] = acquire(&mut free_f32, &mut slab_elems, need);
                    let kind = if chainable(id) {
                        StepKind::Chain(build_chain(g, id, &prev, rows, cols)?)
                    } else {
                        StepKind::Kernel
                    };
                    steps.push(PlanStep { op: id, kind });
                }
                // release sources whose last read is this step (after the
                // output slot is taken, so inputs never alias the output)
                for &r in &frees_at[id] {
                    if slot[r] != NO_SLOT {
                        free_f32.push(slot[r]);
                    } else if i8_slot[r] != NO_SLOT {
                        free_i8.push(i8_slot[r]);
                    }
                }
            }
        }

        // --- density hints: computed MatMul lhs operands are arena
        // activations, dense by construction — skip the per-run probe ---
        let mut density_hint = vec![DensityHint::Sample; n];
        for (id, op) in g.ops.iter().enumerate() {
            if op.kind == OpKind::MatMul && g.ops[op.inputs[0]].kind != OpKind::Input {
                density_hint[id] = DensityHint::NoSkip;
            }
        }

        Ok(ExecPlan {
            graph: g.clone(),
            steps,
            slot,
            i8_slot,
            slab_elems,
            i8_slab_elems,
            sparse_input,
            fused_away,
            kernels,
            density_hint,
        })
    }

    /// True when this plan aggregates through `SpMM` (binds CSR masks).
    pub fn is_sparse(&self) -> bool {
        self.sparse_input.iter().any(|&s| s)
    }

    /// Steady-state f32 arena footprint in bytes.
    pub fn arena_bytes(&self) -> usize {
        self.slab_elems.iter().sum::<usize>() * 4
            + self.i8_slab_elems.iter().sum::<usize>()
    }

    /// What the arena would cost without liveness reuse (every
    /// materialized intermediate its own buffer).
    pub fn unshared_bytes(&self) -> usize {
        let mut total = 0usize;
        for (id, op) in self.graph.ops.iter().enumerate() {
            if self.slot[id] != NO_SLOT {
                total += op.num_elements() * 4;
            } else if self.i8_slot[id] != NO_SLOT {
                total += op.num_elements();
            }
        }
        total
    }

    /// Number of executed steps (fused chains count once).
    pub fn num_steps(&self) -> usize {
        self.steps.len()
    }
}

/// Assemble the chain ending at `tail` by walking `prev` links back to
/// the head, then derive per-stage aux sources and position transforms.
fn build_chain(
    g: &OpGraph,
    tail: OpId,
    prev: &[Option<OpId>],
    rows: usize,
    cols: usize,
) -> Result<Chain> {
    let mut ops = vec![tail];
    let mut cur = tail;
    while let Some(p) = prev[cur] {
        ops.push(p);
        cur = p;
    }
    ops.reverse();

    // walk tail → head accumulating the broadcast position transforms
    let mut pos_at = vec![PosT::default(); ops.len()];
    let mut cur_pos = PosT::default();
    for t in (0..ops.len()).rev() {
        pos_at[t] = cur_pos;
        match g.ops[ops[t]].kind {
            OpKind::BroadcastCol => cur_pos.zero_j = true,
            OpKind::BroadcastRow => cur_pos.zero_i = true,
            _ => {}
        }
    }
    let head_src = g.ops[ops[0]].inputs[0];
    let (hr, hc) = rc(&g.ops[head_src].shape)?;
    let head = ChainSrc { op: head_src, rows: hr, cols: hc, pos: cur_pos };

    let mut aux: Vec<ChainSrc> = Vec::new();
    let mut steps: Vec<FusedOp> = Vec::new();
    for (t, &id) in ops.iter().enumerate() {
        let op = &g.ops[id];
        let is_binary =
            matches!(op.kind, OpKind::Add | OpKind::Sub | OpKind::Mul);
        if is_binary {
            let src = op.inputs[1];
            let (ar, ac) = rc(&g.ops[src].shape)?;
            aux.push(ChainSrc { op: src, rows: ar, cols: ac, pos: pos_at[t] });
        }
        let ax = aux.len().wrapping_sub(1) as u32;
        let step = match op.kind {
            OpKind::Scale(c) => FusedOp::Scale(c),
            OpKind::AddConst(c) => FusedOp::AddConst(c),
            OpKind::Relu => FusedOp::Relu,
            OpKind::LeakyRelu(s) => FusedOp::LeakyRelu(s),
            OpKind::Exp => FusedOp::Exp,
            OpKind::Quantize { scale } => FusedOp::Quantize(scale),
            OpKind::BroadcastCol | OpKind::BroadcastRow => FusedOp::Broadcast,
            OpKind::Add => FusedOp::Add(ax),
            OpKind::Sub => FusedOp::Sub(ax),
            OpKind::Mul => FusedOp::Mul(ax),
            ref other => bail!("op {:?} is not fusible", other.name()),
        };
        steps.push(step);
    }
    Ok(Chain { ops, head, aux, steps, rows, cols })
}

/// Compile-time view of which dtype an op's planned output uses.
pub fn planned_dtype(plan: &ExecPlan, id: OpId) -> DType {
    if plan.i8_slot[id] != NO_SLOT {
        DType::I8
    } else {
        DType::F32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::build::{self, GatVariant, GnnDims, QuantScales};
    use crate::ops::Stage;

    fn dims() -> GnnDims {
        GnnDims { n: 20, m: 30, f: 12, hidden: 8, classes: 4, k: 5, layers: 2 }
    }

    #[test]
    fn compiles_every_builder_variant() {
        for (m, v) in [
            ("gcn", "baseline"),
            ("gcn", "stagr"),
            ("gcn", "quant"),
            ("gat", "baseline"),
            ("gat", "effop"),
            ("gat", "grax"),
            ("sage_mean", "stagr"),
            ("sage_max", "baseline"),
            ("sage_max", "grax3"),
        ] {
            let g = build::build(m, v, dims()).unwrap();
            let p = ExecPlan::compile(&g).unwrap_or_else(|e| panic!("{m}/{v}: {e}"));
            assert!(!p.steps.is_empty());
        }
    }

    #[test]
    fn sparse_plan_marks_csr_inputs_and_avoids_square_slabs() {
        use crate::ops::build::Aggregation;
        let d = dims();
        for (m, v) in [("gcn", "stagr"), ("gcn", "quant"), ("sage_mean", "stagr")] {
            let g = build::build_with(m, v, d, Aggregation::Sparse).unwrap();
            let p = ExecPlan::compile(&g).unwrap_or_else(|e| panic!("{m}/{v}: {e}"));
            assert!(p.is_sparse(), "{m}/{v}");
            // exactly the mask input is sparse
            let marked: Vec<&str> = p
                .graph
                .ops
                .iter()
                .enumerate()
                .filter(|(id, _)| p.sparse_input[*id])
                .map(|(_, op)| op.name.as_str())
                .collect();
            assert_eq!(marked.len(), 1, "{m}/{v}: {marked:?}");
            // no arena slab is n×n — the whole point of the lowering
            assert!(
                p.slab_elems.iter().all(|&e| e < d.n * d.n),
                "{m}/{v}: square slab survived: {:?}",
                p.slab_elems
            );
            // dense twin compiles to the same step count
            let gd = build::build_with(m, v, d, Aggregation::Dense).unwrap();
            let pd = ExecPlan::compile(&gd).unwrap();
            assert_eq!(p.steps.len(), pd.steps.len());
            assert!(!pd.is_sparse());
        }
    }

    #[test]
    fn sparse_operand_feeding_dense_consumer_rejected() {
        // "norm" feeds both an SpMM and a dense Scale: a single binding
        // cannot be CSR and dense at once, so compile must refuse
        let mut g = OpGraph::new("alias");
        let norm = g.input("norm", &[4, 4], DType::F32, Stage::Compute);
        let x = g.input("x", &[4, 3], DType::F32, Stage::Compute);
        let agg = g.op(OpKind::SpMM, &[norm, x], &[4, 3], Stage::Compute);
        let sc = g.op(OpKind::Scale(2.0), &[norm], &[4, 4], Stage::Compute);
        let out = g.op(OpKind::MatMul, &[sc, agg], &[4, 3], Stage::Compute);
        g.set_output(out);
        let err = ExecPlan::compile(&g).unwrap_err().to_string();
        assert!(err.contains("sparse"), "{err}");

        // a computed sparse operand is equally unsupported
        let mut g2 = OpGraph::new("computed");
        let x = g2.input("x", &[4, 4], DType::F32, Stage::Compute);
        let h = g2.input("h", &[4, 3], DType::F32, Stage::Compute);
        let r = g2.op(OpKind::Relu, &[x], &[4, 4], Stage::Compute);
        let agg = g2.op(OpKind::SpMM, &[r, h], &[4, 3], Stage::Compute);
        g2.set_output(agg);
        let err = ExecPlan::compile(&g2).unwrap_err().to_string();
        assert!(err.contains("computed sparse"), "{err}");
    }

    #[test]
    fn arena_reuses_slabs() {
        // deep graphs must share slabs: far fewer slabs than steps, and a
        // smaller steady-state footprint than one-buffer-per-op
        let g = build::gat(dims(), GatVariant::EffOp);
        let p = ExecPlan::compile(&g).unwrap();
        assert!(
            p.slab_elems.len() < p.steps.len(),
            "{} slabs for {} steps",
            p.slab_elems.len(),
            p.steps.len()
        );
        assert!(p.arena_bytes() < p.unshared_bytes());
    }

    #[test]
    fn fusion_mirrors_simulator_contract() {
        let g = build::gat(dims(), GatVariant::EffOp);
        let p = ExecPlan::compile(&g).unwrap();
        let mut chained_ops = 0usize;
        for step in &p.steps {
            if let StepKind::Chain(ch) = &step.kind {
                chained_ops += ch.ops.len();
                for &id in &ch.ops {
                    assert!(
                        crate::npu::sim::is_fusible(&g.ops[id].kind),
                        "chain member {} is not sim-fusible",
                        g.ops[id].kind.name()
                    );
                }
            }
        }
        // every fusible op lands in some chain (as member or singleton)
        let fusible_total = g
            .ops
            .iter()
            .enumerate()
            .filter(|(id, op)| {
                crate::npu::sim::is_fusible(&op.kind) && p.i8_slot[*id] == NO_SLOT
            })
            .count();
        assert_eq!(chained_ops, fusible_total);
        // EffOp's mask arithmetic is exactly the kind of elementwise run
        // the simulator calls free — some real multi-op chain must exist
        assert!(p.fused_away > 0, "no fusion happened");
    }

    #[test]
    fn quantize_feeding_qmatmul_goes_int8() {
        let g = build::gcn_quant(dims(), QuantScales::default());
        let p = ExecPlan::compile(&g).unwrap();
        let quant_steps = p
            .steps
            .iter()
            .filter(|s| matches!(s.kind, StepKind::QuantizeI8 { .. }))
            .count();
        assert_eq!(quant_steps, 2, "both layer activations lower to i8");
        assert!(!p.i8_slab_elems.is_empty());
    }

    #[test]
    fn quantize_with_other_consumers_stays_f32() {
        use crate::ops::Op;
        let mut g = OpGraph::new("qmix");
        let x = g.input("x", &[3, 4], DType::F32, Stage::Compute);
        let w = g.input("w", &[4, 2], DType::F32, Stage::Compute);
        let q = g.push(Op {
            kind: OpKind::Quantize { scale: 0.1 },
            inputs: vec![x],
            shape: vec![3, 4],
            dtype: DType::F32,
            stage: Stage::Compute,
            name: String::new(),
        });
        let mm = g.op(
            OpKind::QMatMul { x_scale: 0.1, w_scale: 0.1 },
            &[q, w],
            &[3, 2],
            Stage::Compute,
        );
        // second consumer: the quantized activations also get ReLU'd
        let r = g.op(OpKind::Relu, &[q], &[3, 4], Stage::Compute);
        let _ = r;
        g.set_output(mm);
        let p = ExecPlan::compile(&g).unwrap();
        assert_eq!(p.i8_slot[q], NO_SLOT, "multi-consumer quantize must stay f32");
        assert!(p.slot[q] != NO_SLOT);
    }

    #[test]
    fn output_never_fused_away() {
        let mut g = OpGraph::new("tailout");
        let x = g.input("x", &[4, 4], DType::F32, Stage::Compute);
        let a = g.op(OpKind::Relu, &[x], &[4, 4], Stage::Compute);
        let b = g.op(OpKind::Scale(2.0), &[a], &[4, 4], Stage::Compute);
        g.set_output(b);
        let p = ExecPlan::compile(&g).unwrap();
        assert!(p.slot[b] != NO_SLOT);
        // relu→scale fuses into one chain of two ops
        assert_eq!(p.steps.len(), 1);
        match &p.steps[0].kind {
            StepKind::Chain(ch) => assert_eq!(ch.ops, vec![a, b]),
            other => panic!("expected chain, got {other:?}"),
        }
    }

    #[test]
    fn broadcast_position_transforms_accumulate() {
        // (m,1) head → BroadcastCol → Add(·, full) : head read at (i, 0)
        let mut g = OpGraph::new("bc");
        let v = g.input("v", &[5, 1], DType::F32, Stage::Compute);
        let full = g.input("full", &[5, 6], DType::F32, Stage::Compute);
        let bc = g.op(OpKind::BroadcastCol, &[v], &[5, 6], Stage::Compute);
        let add = g.op(OpKind::Add, &[bc, full], &[5, 6], Stage::Compute);
        g.set_output(add);
        let p = ExecPlan::compile(&g).unwrap();
        match &p.steps[0].kind {
            StepKind::Chain(ch) => {
                assert!(ch.head.pos.zero_j, "head must be pinned to column 0");
                assert!(!ch.aux[0].pos.zero_j, "aux after the broadcast is not");
            }
            other => panic!("expected chain, got {other:?}"),
        }
    }

    #[test]
    fn raw_input_output_rejected() {
        let mut g = OpGraph::new("io");
        let x = g.input("x", &[2, 2], DType::F32, Stage::Compute);
        g.set_output(x);
        assert!(ExecPlan::compile(&g).is_err());
    }

    #[test]
    fn density_hints_mark_computed_matmul_lhs() {
        // x@w1 has a graph-input lhs (probe per call); (relu(x@w1))@w2
        // has a computed lhs — the plan must pin it dense
        use crate::ops::Stage;
        let mut g = OpGraph::new("hints");
        let x = g.input("x", &[6, 4], DType::F32, Stage::Compute);
        let w1 = g.input("w1", &[4, 3], DType::F32, Stage::Compute);
        let w2 = g.input("w2", &[3, 2], DType::F32, Stage::Compute);
        let h = g.op(OpKind::MatMul, &[x, w1], &[6, 3], Stage::Compute);
        let r = g.op(OpKind::Relu, &[h], &[6, 3], Stage::Compute);
        let o = g.op(OpKind::MatMul, &[r, w2], &[6, 2], Stage::Compute);
        g.set_output(o);
        let p = ExecPlan::compile(&g).unwrap();
        assert_eq!(p.density_hint[h], crate::tensor::DensityHint::Sample);
        assert_eq!(p.density_hint[o], crate::tensor::DensityHint::NoSkip);
        assert_eq!(p.kernels, KernelConfig::default());
        assert!(p.kernels.simd.enabled());
    }

    #[test]
    fn kernel_modes_parse_and_reject() {
        assert_eq!(SimdMode::parse("auto").unwrap(), SimdMode::Auto);
        assert_eq!(SimdMode::parse("on").unwrap(), SimdMode::On);
        assert_eq!(SimdMode::parse("off").unwrap(), SimdMode::Off);
        assert!(!SimdMode::Off.enabled());
        for m in [SimdMode::Auto, SimdMode::On, SimdMode::Off] {
            assert_eq!(SimdMode::parse(m.name()).unwrap(), m);
        }
        let err = SimdMode::parse("avx").unwrap_err().to_string();
        assert!(err.contains("kernels.simd"), "{err}");
        for m in [ReorderMode::None, ReorderMode::Degree, ReorderMode::Rcm] {
            assert_eq!(ReorderMode::parse(m.name()).unwrap(), m);
        }
        let err = ReorderMode::parse("hilbert").unwrap_err().to_string();
        assert!(err.contains("kernels.reorder"), "{err}");
    }

    #[test]
    fn reordering_permutes_and_restores_exactly() {
        use crate::tensor::Mat;
        let g = crate::graph::Graph::new(
            13,
            &(0..20u32).map(|i| (i % 13, (i * 5 + 1) % 13)).collect::<Vec<_>>(),
        );
        let norm = g.norm_csr(13);
        assert!(
            Reordering::compute(ReorderMode::None, &norm.indptr, &norm.indices).is_none()
        );
        for mode in [ReorderMode::Degree, ReorderMode::Rcm] {
            let r = Reordering::compute(mode, &norm.indptr, &norm.indices).unwrap();
            assert_eq!(r.len(), 13);
            // perm ∘ inv = id
            for old in 0..13u32 {
                assert_eq!(r.perm[r.inv[old as usize] as usize], old);
            }
            // row permutation round-trips bitwise
            let x = Mat::from_fn(13, 4, |i, j| (i * 31 + j * 7) as f32 * 0.5);
            assert_eq!(r.restore_rows(&r.permute_rows(&x)), x, "{mode:?}");
            // the permuted CSR is the dense-permuted matrix, rows sorted
            let permuted = r.permute_csr(&norm);
            let dense = norm.to_dense();
            let want =
                Mat::from_fn(13, 13, |i, j| {
                    dense[(r.perm[i] as usize, r.perm[j] as usize)]
                });
            assert_eq!(permuted.to_dense(), want, "{mode:?}");
            for i in 0..13 {
                let (cols, _) = permuted.row_entries(i);
                assert!(cols.windows(2).all(|w| w[0] < w[1]), "row {i} unsorted");
            }
        }
    }
}
