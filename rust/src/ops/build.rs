//! Op-graph builders for GCN / GAT / GraphSAGE — the baseline
//! (out-of-the-box NPU mapping) and every GraNNite variant.
//!
//! Input naming matches the AOT artifacts (`python/compile/aot.py`), so a
//! built graph, the PJRT executable, and the simulator all agree on what
//! gets bound at runtime.
//!
//! Aggregation is emitted in one of two forms, selected by
//! [`Aggregation`]: the dense `MatMul` against the materialized norm
//! mask (the oracle path, and the right call for dense masks), or the
//! sparse-native [`OpKind::SpMM`] against the same mask bound as a
//! [`crate::tensor::Tensor::Csr`] operand — O(nnz·d) instead of
//! O(n²·d), which at citation-graph density (~0.1%) is the difference
//! between the aggregation dominating and vanishing.

use anyhow::{anyhow, bail, Result};

use super::{OpGraph, OpId, OpKind, Stage, LEAKY_SLOPE, NEG_MASK};
use crate::tensor::DType;

/// Mask density below which the SpMM lowering beats the dense MatMul
/// (same measured crossover family as
/// [`crate::tensor::SKIP_DENSITY_THRESHOLD`]: below it, per-entry
/// indexing costs less than streaming the zeros; the cost model in
/// [`crate::npu::cost`] agrees — see its crossover test).
pub const SPMM_DENSITY_THRESHOLD: f64 = 0.25;

/// How builders lower the aggregation step. `Auto` resolves per graph
/// from the mask density ([`Aggregation::resolve`]); builders treat an
/// unresolved `Auto` as `Dense` (the oracle-compatible default), so
/// callers that care resolve first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Aggregation {
    /// n×n `MatMul` against the dense mask (the property-test oracle).
    Dense,
    /// [`OpKind::SpMM`] against the CSR-bound mask.
    Sparse,
    /// Pick per graph: sparse below [`SPMM_DENSITY_THRESHOLD`].
    #[default]
    Auto,
}

impl Aggregation {
    /// Parse a `--aggregation dense|sparse|auto` flag.
    pub fn parse(s: &str) -> Result<Aggregation> {
        match s {
            "dense" => Ok(Aggregation::Dense),
            "sparse" => Ok(Aggregation::Sparse),
            "auto" => Ok(Aggregation::Auto),
            other => Err(anyhow!(
                "--aggregation must be dense|sparse|auto, got {other:?}"
            )),
        }
    }

    /// Resolve `Auto` against a mask density (never returns `Auto`).
    pub fn resolve(self, density: f64) -> Aggregation {
        match self {
            Aggregation::Auto => {
                if density < SPMM_DENSITY_THRESHOLD {
                    Aggregation::Sparse
                } else {
                    Aggregation::Dense
                }
            }
            fixed => fixed,
        }
    }

    /// Does this (resolved) mode emit `SpMM`?
    pub fn lowers_sparse(self) -> bool {
        self == Aggregation::Sparse
    }

    pub fn name(self) -> &'static str {
        match self {
            Aggregation::Dense => "dense",
            Aggregation::Sparse => "sparse",
            Aggregation::Auto => "auto",
        }
    }

    /// The aggregation op kind this mode emits.
    fn op_kind(self) -> OpKind {
        if self.lowers_sparse() {
            OpKind::SpMM
        } else {
            OpKind::MatMul
        }
    }
}

/// Model dimensions shared by all builders.
#[derive(Debug, Clone, Copy)]
pub struct GnnDims {
    /// Node count the graph is built at (= NodePad capacity when padded).
    pub n: usize,
    /// Edge count (sizes the edge-list input of baseline graphs).
    pub m: usize,
    /// Input feature width.
    pub f: usize,
    /// Hidden width (paper: 64).
    pub hidden: usize,
    /// Output classes.
    pub classes: usize,
    /// SAGE gather width (max neighbors + 1).
    pub k: usize,
    /// Number of GNN layers (2 for the full models, 1 for Fig. 4/5).
    pub layers: usize,
}

impl GnnDims {
    /// The paper's standard 2-layer model at dataset scale.
    pub fn model(n: usize, m: usize, f: usize, classes: usize) -> GnnDims {
        GnnDims { n, m, f, hidden: crate::HIDDEN, classes, k: crate::SAGE_MAX_NEIGHBORS + 1, layers: 2 }
    }

    /// Fig. 4/5 microbenchmark: one layer, 1433 → 64.
    pub fn fig4(n: usize, m: usize) -> GnnDims {
        GnnDims { n, m, f: 1433, hidden: 64, classes: 64, k: crate::SAGE_MAX_NEIGHBORS + 1, layers: 1 }
    }

    fn out_width(&self, layer: usize) -> usize {
        if layer + 1 == self.layers {
            self.classes
        } else {
            self.hidden
        }
    }
}

/// QuantGr static scales (from calibration; defaults are typical of the
/// trained Cora twin and only matter for executor numerics, not timing).
#[derive(Debug, Clone, Copy)]
pub struct QuantScales {
    pub act1: f32,
    pub w1: f32,
    pub act2: f32,
    pub w2: f32,
}

impl Default for QuantScales {
    fn default() -> Self {
        QuantScales { act1: 0.01, w1: 0.005, act2: 0.05, w2: 0.01 }
    }
}

/// Build a model variant by name (the CLI/bench entry point) with the
/// dense aggregation (artifact-compatible shapes; the oracle default).
pub fn build(model: &str, variant: &str, dims: GnnDims) -> Result<OpGraph> {
    build_with(model, variant, dims, Aggregation::Dense)
}

/// Build a model variant with an explicit aggregation lowering. Models
/// whose aggregation is data-dependent (GAT attention) or already
/// non-matmul (SAGE-max gather / GrAx3 max-pool) ignore the mode.
pub fn build_with(model: &str, variant: &str, dims: GnnDims,
                  agg: Aggregation) -> Result<OpGraph> {
    Ok(match (model, variant) {
        ("gcn", "baseline") => gcn_baseline(dims),
        ("gcn", "stagr") | ("gcn", "grad") => gcn_stagr_with(dims, variant, agg),
        ("gcn", "quant") => gcn_quant_with(dims, QuantScales::default(), agg),
        ("gat", "baseline") => gat(dims, GatVariant::Baseline),
        ("gat", "effop") => gat(dims, GatVariant::EffOp),
        ("gat", "grax") => gat(dims, GatVariant::Grax),
        ("sage_mean", "stagr") | ("sage_mean", "baseline") => {
            sage_mean_with(dims, agg)
        }
        ("sage_max", "baseline") => sage_max_baseline(dims),
        ("sage_max", "grax3") => sage_max_grax3(dims),
        (m, v) => bail!("unknown model/variant {m:?}/{v:?}"),
    })
}

// ---------------------------------------------------------------------------
// GCN
// ---------------------------------------------------------------------------

/// Out-of-the-box GraphConv mapping: the whole Fig. 3 pipeline on-device.
/// Preprocessing materializes the dense normalization matrix from the raw
/// edge list — adjacency build, degree count, square root, and two n×n
/// divisions, all control-heavy DSP work. This is the ~99%-preprocessing
/// regime Fig. 4 reports; PreG/StaGr exist to delete exactly these ops.
pub fn gcn_baseline(d: GnnDims) -> OpGraph {
    let mut g = OpGraph::new("gcn_baseline");
    let edges = g.input("edges", &[d.m, 2], DType::I32, Stage::Preprocess);
    let x = g.input("x", &[d.n, d.f], DType::F32, Stage::Compute);

    // preprocessing: A+I, D, √D, then norm = (A+I) / √D ⊘ √Dᵀ
    let adj = g.op(OpKind::AdjacencyFromEdges, &[edges], &[d.n, d.n], Stage::Preprocess);
    let deg = g.op(OpKind::DegreesFromEdges, &[edges], &[d.n, 1], Stage::Preprocess);
    let sq = g.op(OpKind::Sqrt, &[deg], &[d.n, 1], Stage::Preprocess);
    let n1 = g.op(OpKind::Div, &[adj, sq], &[d.n, d.n], Stage::Preprocess);
    let sqt = g.op(OpKind::Transpose, &[sq], &[1, d.n], Stage::Preprocess);
    let norm = g.op(OpKind::Div, &[n1, sqt], &[d.n, d.n], Stage::Preprocess);

    let mut h = x;
    let mut width = d.f;
    for layer in 0..d.layers {
        let out_w = d.out_width(layer);
        let w = g.input(&format!("w{}", layer + 1), &[width, out_w], DType::F32, Stage::Compute);
        let b = g.input(&format!("b{}", layer + 1), &[1, out_w], DType::F32, Stage::Compute);
        let mm = g.op(OpKind::MatMul, &[h, w], &[d.n, out_w], Stage::Compute);
        let agg = g.op(OpKind::MatMul, &[norm, mm], &[d.n, out_w], Stage::Compute);
        let mut out = g.op(OpKind::Add, &[agg, b], &[d.n, out_w], Stage::Compute);
        if layer + 1 < d.layers {
            out = g.op(OpKind::Relu, &[out], &[d.n, out_w], Stage::Compute);
        }
        h = out;
        width = out_w;
    }
    g.set_output(h);
    g
}

/// StaGr + PreG (+ GrAd when the mask is fed per-request): aggregation
/// against the precomputed `norm` input; zero preprocessing ops remain on
/// the NPU. Dense lowering (the oracle path; see [`gcn_stagr_with`] for
/// the SpMM variant).
pub fn gcn_stagr(d: GnnDims, name: &str) -> OpGraph {
    gcn_stagr_with(d, name, Aggregation::Dense)
}

/// [`gcn_stagr`] with an explicit aggregation lowering: `Sparse` emits
/// [`OpKind::SpMM`] — the `norm` input keeps its name and logical
/// `[n, n]` shape but binds a CSR tensor, so shard memory scales with
/// nnz instead of n².
pub fn gcn_stagr_with(d: GnnDims, name: &str, agg: Aggregation) -> OpGraph {
    let sparse = agg.lowers_sparse();
    let mut g = OpGraph::new(if sparse {
        format!("gcn_{name}_spmm")
    } else {
        format!("gcn_{name}")
    });
    let norm = g.input("norm", &[d.n, d.n], DType::F32, Stage::Compute);
    let x = g.input("x", &[d.n, d.f], DType::F32, Stage::Compute);
    let mut h = x;
    let mut width = d.f;
    for layer in 0..d.layers {
        let out_w = d.out_width(layer);
        let w = g.input(&format!("w{}", layer + 1), &[width, out_w], DType::F32, Stage::Compute);
        let b = g.input(&format!("b{}", layer + 1), &[1, out_w], DType::F32, Stage::Compute);
        // combination first (f → f'), then the sparse/dense aggregation
        let mm = g.op(OpKind::MatMul, &[h, w], &[d.n, out_w], Stage::Compute);
        let agg_id = g.op(agg.op_kind(), &[norm, mm], &[d.n, out_w], Stage::Compute);
        let mut out = g.op(OpKind::Add, &[agg_id, b], &[d.n, out_w], Stage::Compute);
        if layer + 1 < d.layers {
            out = g.op(OpKind::Relu, &[out], &[d.n, out_w], Stage::Compute);
        }
        h = out;
        width = out_w;
    }
    g.set_output(h);
    g
}

/// QuantGr on top of StaGr: INT8 combination MatMuls with static scales.
/// Dense aggregation (see [`gcn_quant_with`]).
pub fn gcn_quant(d: GnnDims, s: QuantScales) -> OpGraph {
    gcn_quant_with(d, s, Aggregation::Dense)
}

/// [`gcn_quant`] with an explicit aggregation lowering: the INT8
/// combination path is unchanged, the aggregation becomes SpMM.
pub fn gcn_quant_with(d: GnnDims, s: QuantScales, agg: Aggregation) -> OpGraph {
    let sparse = agg.lowers_sparse();
    let mut g = OpGraph::new(if sparse { "gcn_quant_spmm" } else { "gcn_quant" });
    let norm = g.input("norm", &[d.n, d.n], DType::F32, Stage::Compute);
    let x = g.input("x", &[d.n, d.f], DType::F32, Stage::Compute);

    let scales = [(s.act1, s.w1), (s.act2, s.w2)];
    let mut h = x;
    let mut width = d.f;
    for layer in 0..d.layers {
        let out_w = d.out_width(layer);
        let (sa, sw) = scales[layer.min(1)];
        let mut w = g.input(&format!("w{}q", layer + 1), &[width, out_w], DType::I8, Stage::Compute);
        // weight tensors arrive pre-quantized; mark dtype
        g.ops[w].dtype = DType::I8;
        let b = g.input(&format!("b{}", layer + 1), &[1, out_w], DType::F32, Stage::Compute);
        let hq = g.op(OpKind::Quantize { scale: sa }, &[h], &[d.n, width], Stage::Compute);
        g.ops[hq].dtype = DType::I8;
        // weights already int8-valued; QMatMul dequantizes
        let mm = g.op(
            OpKind::QMatMul { x_scale: sa, w_scale: sw },
            &[hq, w],
            &[d.n, out_w],
            Stage::Compute,
        );
        let agg_id = g.op(agg.op_kind(), &[norm, mm], &[d.n, out_w], Stage::Compute);
        let mut out = g.op(OpKind::Add, &[agg_id, b], &[d.n, out_w], Stage::Compute);
        if layer + 1 < d.layers {
            out = g.op(OpKind::Relu, &[out], &[d.n, out_w], Stage::Compute);
        }
        h = out;
        width = out_w;
        let _ = &mut w;
    }
    g.set_output(h);
    g
}

/// One GCN layer over a **node subset** — the unit the incremental
/// engine's gather/scatter path executes ([`crate::incremental`]).
///
/// `rows` is the padded frontier tile (output rows to recompute), `ring`
/// the padded one-hop input ring. The caller gathers `h_ring` (ring rows
/// of the layer input) and `norm_sub` (the `rows × ring` slice of the
/// GrAd norm mask) into the tile; the graph then mirrors one
/// [`gcn_stagr`] layer exactly — combination MatMul, aggregation MatMul,
/// bias add, optional ReLU — so a frontier recompute is bit-comparable
/// to the same rows of a full-graph pass (padding columns are zero in
/// `norm_sub`, contributing exact-zero terms).
pub fn gcn_layer_tile(rows: usize, ring: usize, in_w: usize, out_w: usize,
                      relu: bool) -> OpGraph {
    let mut g = OpGraph::new(format!("gcn_tile_{rows}x{ring}_{in_w}to{out_w}"));
    let h = g.input("h_ring", &[ring, in_w], DType::F32, Stage::Compute);
    let norm = g.input("norm_sub", &[rows, ring], DType::F32, Stage::Compute);
    let w = g.input("w", &[in_w, out_w], DType::F32, Stage::Compute);
    let b = g.input("b", &[1, out_w], DType::F32, Stage::Compute);
    let mm = g.op(OpKind::MatMul, &[h, w], &[ring, out_w], Stage::Compute);
    let agg = g.op(OpKind::MatMul, &[norm, mm], &[rows, out_w], Stage::Compute);
    let mut out = g.op(OpKind::Add, &[agg, b], &[rows, out_w], Stage::Compute);
    if relu {
        out = g.op(OpKind::Relu, &[out], &[rows, out_w], Stage::Compute);
    }
    g.set_output(out);
    g
}

// ---------------------------------------------------------------------------
// GAT
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GatVariant {
    /// Select(adj, e, −inf) masking; monolithic DSP SoftMax; explicit
    /// broadcast + transpose score assembly (Fig. 5's 30%-DSP regime).
    /// Adjacency is built on-device from the edge list (Fig. 4's
    /// preprocessing-heavy out-of-the-box mapping).
    Baseline,
    /// Same compute path, but the adjacency mask arrives as a StaGr
    /// precomputed input — the "enabled" baseline the Fig. 20 ladder
    /// starts from (preprocessing already off-device).
    BaselineMasked,
    /// EffOp: masking via mask-multiply + complement bias, SoftMax
    /// decomposed into DPU reductions + one DSP reciprocal (Fig. 12).
    EffOp,
    /// GrAx1 (additive −1e9 mask input) + GrAx2 (add-then-broadcast,
    /// dropping the n×n transpose) on top of EffOp (Figs. 16–17).
    Grax,
}

/// Build a GAT model (single attention head per layer, as evaluated).
pub fn gat(d: GnnDims, variant: GatVariant) -> OpGraph {
    let name = match variant {
        GatVariant::Baseline => "gat_baseline",
        GatVariant::BaselineMasked => "gat_baseline_masked",
        GatVariant::EffOp => "gat_effop",
        GatVariant::Grax => "gat_grax",
    };
    let mut g = OpGraph::new(name);

    // mask source
    let (edges, mask) = match variant {
        GatVariant::BaselineMasked | GatVariant::EffOp => {
            // StaGr: precomputed attention mask arrives as an input
            let adj = g.input("adj", &[d.n, d.n], DType::F32, Stage::Compute);
            (None, adj)
        }
        GatVariant::Baseline => {
            // on-device preprocessing builds the dense adjacency (DSP)
            let e = g.input("edges", &[d.m, 2], DType::I32, Stage::Preprocess);
            let adj = g.op(OpKind::AdjacencyFromEdges, &[e], &[d.n, d.n], Stage::Preprocess);
            (Some(e), adj)
        }
        GatVariant::Grax => {
            // GrAd: the additive mask is a runtime input, prepared CPU-side
            let nb = g.input("neg_bias", &[d.n, d.n], DType::F32, Stage::Compute);
            (None, nb)
        }
    };
    let _ = edges;
    let x = g.input("x", &[d.n, d.f], DType::F32, Stage::Compute);

    let mut h = x;
    let mut width = d.f;
    for layer in 0..d.layers {
        let out_w = d.out_width(layer);
        let l = layer + 1;
        let w = g.input(&format!("w{l}"), &[width, out_w], DType::F32, Stage::Compute);
        let a_src = g.input(&format!("a{l}_src"), &[out_w, 1], DType::F32, Stage::Compute);
        let a_dst = g.input(&format!("a{l}_dst"), &[out_w, 1], DType::F32, Stage::Compute);
        let b = g.input(&format!("b{l}"), &[1, out_w], DType::F32, Stage::Compute);

        let hw = g.op(OpKind::MatMul, &[h, w], &[d.n, out_w], Stage::Compute);
        let s = g.op(OpKind::MatMul, &[hw, a_src], &[d.n, 1], Stage::Compute);
        let t = g.op(OpKind::MatMul, &[hw, a_dst], &[d.n, 1], Stage::Compute);

        // score assembly e[i,j] = s_i + t_j
        let e = match variant {
            GatVariant::Baseline | GatVariant::BaselineMasked | GatVariant::EffOp => {
                // broadcast-add with an n×n transpose (GrAx2's target)
                let sb = g.op(OpKind::BroadcastCol, &[s], &[d.n, d.n], Stage::Compute);
                let tb = g.op(OpKind::BroadcastCol, &[t], &[d.n, d.n], Stage::Compute);
                let tt = g.op(OpKind::Transpose, &[tb], &[d.n, d.n], Stage::Compute);
                g.op(OpKind::Add, &[sb, tt], &[d.n, d.n], Stage::Compute)
            }
            GatVariant::Grax => {
                // GrAx2: transpose the (n,1) vector, broadcast once
                let tt = g.op(OpKind::Transpose, &[t], &[1, d.n], Stage::Compute);
                let tb = g.op(OpKind::BroadcastRow, &[tt], &[d.n, d.n], Stage::Compute);
                g.op(OpKind::Add, &[tb, s], &[d.n, d.n], Stage::Compute)
            }
        };
        let e = g.op(OpKind::LeakyRelu(LEAKY_SLOPE), &[e], &[d.n, d.n], Stage::Compute);

        // masking
        let masked = match variant {
            GatVariant::Baseline | GatVariant::BaselineMasked => {
                let zero = g.op(OpKind::Scale(0.0), &[e], &[d.n, d.n], Stage::Compute);
                let neg = g.op(OpKind::AddConst(NEG_MASK), &[zero], &[d.n, d.n], Stage::Compute);
                g.op(OpKind::Select, &[mask, e, neg], &[d.n, d.n], Stage::Compute)
            }
            GatVariant::EffOp => {
                // e*adj + (1-adj)*NEG — pure elementwise DPU work
                let on = g.op(OpKind::Mul, &[e, mask], &[d.n, d.n], Stage::Compute);
                let zero = g.op(OpKind::Scale(0.0), &[mask], &[d.n, d.n], Stage::Compute);
                let ones = g.op(OpKind::AddConst(1.0), &[zero], &[d.n, d.n], Stage::Compute);
                let comp = g.op(OpKind::Sub, &[ones, mask], &[d.n, d.n], Stage::Compute);
                let off = g.op(OpKind::Scale(NEG_MASK), &[comp], &[d.n, d.n], Stage::Compute);
                g.op(OpKind::Add, &[on, off], &[d.n, d.n], Stage::Compute)
            }
            GatVariant::Grax => {
                // GrAx1: one elementwise add of the precomputed bias
                g.op(OpKind::Add, &[e, mask], &[d.n, d.n], Stage::Compute)
            }
        };

        // softmax
        let attn = match variant {
            GatVariant::Baseline | GatVariant::BaselineMasked => {
                g.op(OpKind::Softmax, &[masked], &[d.n, d.n], Stage::Compute)
            }
            GatVariant::EffOp | GatVariant::Grax => {
                // decomposed: DPU reductions + (n,1) DSP reciprocal
                let mx = g.op(OpKind::ReduceMaxRows, &[masked], &[d.n, 1], Stage::Compute);
                let sh = g.op(OpKind::Sub, &[masked, mx], &[d.n, d.n], Stage::Compute);
                let ex = g.op(OpKind::Exp, &[sh], &[d.n, d.n], Stage::Compute);
                let sm = g.op(OpKind::ReduceSumRows, &[ex], &[d.n, 1], Stage::Compute);
                let rc = g.op(OpKind::Reciprocal, &[sm], &[d.n, 1], Stage::Compute);
                g.op(OpKind::Mul, &[ex, rc], &[d.n, d.n], Stage::Compute)
            }
        };

        let agg = g.op(OpKind::MatMul, &[attn, hw], &[d.n, out_w], Stage::Compute);
        let mut out = g.op(OpKind::Add, &[agg, b], &[d.n, out_w], Stage::Compute);
        if layer + 1 < d.layers {
            out = g.op(OpKind::Elu, &[out], &[d.n, out_w], Stage::Compute);
        }
        h = out;
        width = out_w;
    }
    g.set_output(h);
    g
}

// ---------------------------------------------------------------------------
// GraphSAGE
// ---------------------------------------------------------------------------

fn sage_skeleton(
    g: &mut OpGraph,
    d: GnnDims,
    x: OpId,
    mut agg: impl FnMut(&mut OpGraph, OpId, usize) -> OpId,
) -> OpId {
    let mut h = x;
    let mut width = d.f;
    for layer in 0..d.layers {
        let out_w = d.out_width(layer);
        let l = layer + 1;
        let ws = g.input(&format!("w{l}_self"), &[width, out_w], DType::F32, Stage::Compute);
        let wn = g.input(&format!("w{l}_neigh"), &[width, out_w], DType::F32, Stage::Compute);
        let b = g.input(&format!("b{l}"), &[1, out_w], DType::F32, Stage::Compute);
        let hs = g.op(OpKind::MatMul, &[h, ws], &[d.n, out_w], Stage::Compute);
        let hn_in = agg(g, h, width);
        let hn = g.op(OpKind::MatMul, &[hn_in, wn], &[d.n, out_w], Stage::Compute);
        let sum = g.op(OpKind::Add, &[hs, hn], &[d.n, out_w], Stage::Compute);
        let mut out = g.op(OpKind::Add, &[sum, b], &[d.n, out_w], Stage::Compute);
        if layer + 1 < d.layers {
            out = g.op(OpKind::Relu, &[out], &[d.n, out_w], Stage::Compute);
        }
        h = out;
        width = out_w;
    }
    h
}

/// SAGE-mean, StaGr-style: aggregation against the row-normalized
/// sampled mask (prepared CPU-side; PreG applied to the degree divide).
/// Dense lowering (see [`sage_mean_with`]).
pub fn sage_mean(d: GnnDims) -> OpGraph {
    sage_mean_with(d, Aggregation::Dense)
}

/// [`sage_mean`] with an explicit aggregation lowering: the sampled mask
/// caps each row at k+1 entries, so its density is ≤ (k+1)/n and SpMM
/// wins at any realistic scale.
pub fn sage_mean_with(d: GnnDims, agg: Aggregation) -> OpGraph {
    let sparse = agg.lowers_sparse();
    let mut g = OpGraph::new(if sparse { "sage_mean_spmm" } else { "sage_mean" });
    let mask = g.input("norm_mask", &[d.n, d.n], DType::F32, Stage::Compute);
    let x = g.input("x", &[d.n, d.f], DType::F32, Stage::Compute);
    let out = sage_skeleton(&mut g, d, x, |g, h, width| {
        g.op(agg.op_kind(), &[mask, h], &[d.n, width], Stage::Compute)
    });
    g.set_output(out);
    g
}

/// SAGE-mean over the gathered index matrix — the formulation CPU/GPU
/// runtimes use (gathers are cheap there; no dense n×n mask needed).
pub fn sage_mean_gathered(d: GnnDims) -> OpGraph {
    let mut g = OpGraph::new("sage_mean_gathered");
    let idx = g.input("nbr_idx", &[d.n, d.k], DType::I32, Stage::Compute);
    let x = g.input("x", &[d.n, d.f], DType::F32, Stage::Compute);
    let out = sage_skeleton(&mut g, d, x, |g, h, width| {
        g.op(OpKind::NeighborGatherMean, &[idx, h], &[d.n, width], Stage::Compute)
    });
    g.set_output(out);
    g
}

/// SAGE-max, baseline: sequential gather-and-compare on the DSP.
pub fn sage_max_baseline(d: GnnDims) -> OpGraph {
    let mut g = OpGraph::new("sage_max_baseline");
    let idx = g.input("nbr_idx", &[d.n, d.k], DType::I32, Stage::Compute);
    let x = g.input("x", &[d.n, d.f], DType::F32, Stage::Compute);
    let out = sage_skeleton(&mut g, d, x, |g, h, width| {
        g.op(OpKind::NeighborGatherMax, &[idx, h], &[d.n, width], Stage::Compute)
    });
    g.set_output(out);
    g
}

/// SAGE-max with GrAx3: mask-multiply + max-pool on the DPU (Fig. 18).
pub fn sage_max_grax3(d: GnnDims) -> OpGraph {
    let mut g = OpGraph::new("sage_max_grax3");
    let mask = g.input("mask", &[d.n, d.n], DType::F32, Stage::Compute);
    let x = g.input("x", &[d.n, d.f], DType::F32, Stage::Compute);
    let out = sage_skeleton(&mut g, d, x, |g, h, width| {
        g.op(OpKind::MaskedMaxPool, &[mask, h], &[d.n, width], Stage::Compute)
    });
    g.set_output(out);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::Engine;

    fn dims() -> GnnDims {
        GnnDims { n: 20, m: 30, f: 12, hidden: 8, classes: 4, k: 5, layers: 2 }
    }

    #[test]
    fn all_builders_validate() {
        for (m, v) in [
            ("gcn", "baseline"),
            ("gcn", "stagr"),
            ("gcn", "grad"),
            ("gcn", "quant"),
            ("gat", "baseline"),
            ("gat", "effop"),
            ("gat", "grax"),
            ("sage_mean", "stagr"),
            ("sage_max", "baseline"),
            ("sage_max", "grax3"),
        ] {
            let g = build(m, v, dims()).unwrap();
            g.validate().unwrap_or_else(|e| panic!("{m}/{v}: {e}"));
        }
        assert!(build("gcn", "nope", dims()).is_err());
    }

    #[test]
    fn sparse_lowering_swaps_aggregation_only() {
        for (m, v, aggs) in [
            ("gcn", "stagr", 2usize),
            ("gcn", "grad", 2),
            ("gcn", "quant", 2),
            ("sage_mean", "stagr", 2),
        ] {
            let dense = build_with(m, v, dims(), Aggregation::Dense).unwrap();
            let sparse = build_with(m, v, dims(), Aggregation::Sparse).unwrap();
            sparse.validate().unwrap();
            assert_eq!(dense.len(), sparse.len(), "{m}/{v}: op count must match");
            assert_eq!(sparse.op_histogram().get("SpMM"), Some(&aggs), "{m}/{v}");
            assert_eq!(dense.op_histogram().get("SpMM"), None);
            // only the aggregation ops differ; shapes and inputs are equal
            for (a, b) in dense.ops.iter().zip(&sparse.ops) {
                assert_eq!(a.shape, b.shape);
                assert_eq!(a.inputs, b.inputs);
                if a.kind != b.kind {
                    assert_eq!(a.kind, OpKind::MatMul);
                    assert_eq!(b.kind, OpKind::SpMM);
                }
            }
            // input naming is unchanged — the runtime binds CSR by name
            let dn: Vec<&str> = dense.inputs().into_iter().map(|(_, n)| n).collect();
            let sn: Vec<&str> = sparse.inputs().into_iter().map(|(_, n)| n).collect();
            assert_eq!(dn, sn);
        }
        // GAT/SAGE-max ignore the mode (no matmul-shaped aggregation mask)
        let g = build_with("gat", "grax", dims(), Aggregation::Sparse).unwrap();
        assert_eq!(g.op_histogram().get("SpMM"), None);
    }

    #[test]
    fn aggregation_auto_resolves_by_density() {
        assert_eq!(Aggregation::Auto.resolve(0.001), Aggregation::Sparse);
        assert_eq!(Aggregation::Auto.resolve(0.5), Aggregation::Dense);
        assert_eq!(Aggregation::Dense.resolve(0.001), Aggregation::Dense);
        assert_eq!(Aggregation::Sparse.resolve(0.9), Aggregation::Sparse);
        assert_eq!(Aggregation::parse("sparse").unwrap(), Aggregation::Sparse);
        assert_eq!(Aggregation::parse("auto").unwrap(), Aggregation::Auto);
        assert!(Aggregation::parse("csr").is_err());
        assert!(!Aggregation::Auto.lowers_sparse(), "unresolved auto = dense");
    }

    #[test]
    fn stagr_has_no_preprocess_or_dsp_ops() {
        let g = gcn_stagr(dims(), "stagr");
        assert!(g
            .ops
            .iter()
            .all(|op| op.stage != Stage::Preprocess));
        assert!(g.ops.iter().all(|op| op.kind == OpKind::Input
            || op.kind.default_engine() == Engine::Dpu));
    }

    #[test]
    fn baseline_has_dsp_preprocessing() {
        let g = gcn_baseline(dims());
        let pre: Vec<_> = g
            .ops
            .iter()
            .filter(|op| op.stage == Stage::Preprocess && op.kind != OpKind::Input)
            .collect();
        assert!(!pre.is_empty());
        // the bulk of preprocessing is DSP-class (one small Transpose aside)
        let dsp = pre
            .iter()
            .filter(|op| op.kind.default_engine() == Engine::Dsp)
            .count();
        assert!(dsp >= pre.len() - 1, "{dsp}/{}", pre.len());
        // PreG's targets present: Sqrt + the two n×n normalization Divs
        let h = g.op_histogram();
        assert_eq!(h.get("Sqrt"), Some(&1));
        assert_eq!(h.get("Div"), Some(&2));
        assert_eq!(h.get("BuildAdj"), Some(&1));
    }

    #[test]
    fn gat_variant_op_mix_matches_paper() {
        let base = gat(dims(), GatVariant::Baseline).op_histogram();
        let eff = gat(dims(), GatVariant::EffOp).op_histogram();
        let grax = gat(dims(), GatVariant::Grax).op_histogram();
        // baseline: Select + monolithic Softmax present
        assert!(base.get("Select").is_some());
        assert!(base.get("Softmax").is_some());
        // EffOp eliminates both
        assert!(eff.get("Select").is_none());
        assert!(eff.get("Softmax").is_none());
        assert!(eff.get("Reciprocal").is_some());
        // GrAx drops the preprocessing BuildAdj and the extra muls
        assert!(grax.get("BuildAdj").is_none());
        assert!(base.get("BuildAdj").is_some());
        assert!(grax.get("Mul").unwrap() < eff.get("Mul").unwrap());
    }

    #[test]
    fn grax2_removes_square_transpose() {
        // baseline transposes an n×n; grax transposes only (n,1)
        let d = dims();
        let base = gat(d, GatVariant::Baseline);
        let grax = gat(d, GatVariant::Grax);
        let max_transpose_elems = |g: &OpGraph| {
            g.ops
                .iter()
                .filter(|op| op.kind == OpKind::Transpose)
                .map(|op| op.num_elements())
                .max()
                .unwrap_or(0)
        };
        assert_eq!(max_transpose_elems(&base), d.n * d.n);
        assert_eq!(max_transpose_elems(&grax), d.n);
    }

    #[test]
    fn sage_variants_aggregate_differently() {
        let b = sage_max_baseline(dims()).op_histogram();
        let x = sage_max_grax3(dims()).op_histogram();
        assert_eq!(b.get("GatherMax"), Some(&2));
        assert!(x.get("GatherMax").is_none());
        assert_eq!(x.get("MaxPool"), Some(&2));
    }

    #[test]
    fn quant_marks_int8_operands() {
        let g = gcn_quant(dims(), QuantScales::default());
        let int8_inputs: Vec<_> = g
            .ops
            .iter()
            .filter(|op| op.kind == OpKind::Input && op.dtype == DType::I8)
            .map(|op| op.name.clone())
            .collect();
        assert_eq!(int8_inputs, vec!["w1q", "w2q"]);
        assert!(g.ops.iter().any(|op| matches!(op.kind, OpKind::QMatMul { .. })));
    }

    #[test]
    fn single_layer_dims_for_fig4() {
        let d = GnnDims::fig4(1354, 5429);
        let g = gcn_baseline(d);
        g.validate().unwrap();
        // one layer → combination + aggregation MatMuls
        assert_eq!(g.op_histogram().get("MatMul"), Some(&2));
        let gat_g = gat(d, GatVariant::Baseline);
        gat_g.validate().unwrap();
    }

    #[test]
    fn input_names_match_artifacts() {
        // the runtime binds artifacts by these names; keep them stable
        let g = gcn_stagr(GnnDims::model(30, 60, 16, 4), "stagr");
        let names: Vec<&str> = g.inputs().into_iter().map(|(_, n)| n).collect();
        assert_eq!(names, vec!["norm", "x", "w1", "b1", "w2", "b2"]);
        let g = gat(GnnDims::model(30, 60, 16, 4), GatVariant::Grax);
        let names: Vec<&str> = g.inputs().into_iter().map(|(_, n)| n).collect();
        assert_eq!(
            names,
            vec!["neg_bias", "x", "w1", "a1_src", "a1_dst", "b1", "w2", "a2_src", "a2_dst", "b2"]
        );
    }
}
