//! Reference executor for op graphs — the correctness oracle every
//! rewrite pass is verified against, and the "CPU device" GraphSplit
//! assigns control-heavy stages to.
//!
//! Numerics mirror `python/compile/kernels/ref.py` (LeakyReLU slope,
//! NEG_MASK, sentinel-aware gathers, symmetric INT8 semantics) so results
//! are comparable across all three layers.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use super::{Op, OpGraph, OpKind};
use crate::tensor::{Mat, Tensor};

/// Runtime bindings for named graph inputs.
pub type Bindings = BTreeMap<String, Tensor>;

/// Execute the graph, returning the tensor of each output id.
pub fn execute(g: &OpGraph, bindings: &Bindings) -> Result<Vec<Tensor>> {
    let mut values: Vec<Option<Tensor>> = vec![None; g.ops.len()];
    for id in g.topo_order() {
        let op = &g.ops[id];
        let val = eval_op(g, op, &values, bindings)
            .with_context(|| format!("{} op#{id} {}", g.name, op.kind.name()))?;
        values[id] = Some(val);
    }
    g.outputs
        .iter()
        .map(|&o| {
            values[o]
                .clone()
                .ok_or_else(|| anyhow!("output {o} not computed"))
        })
        .collect()
}

/// Execute and return the single output as a matrix.
pub fn execute_mat(g: &OpGraph, bindings: &Bindings) -> Result<Mat> {
    let outs = execute(g, bindings)?;
    outs[0].to_mat()
}

fn eval_op(_g: &OpGraph, op: &Op, values: &[Option<Tensor>],
           bindings: &Bindings) -> Result<Tensor> {
    let arg = |k: usize| -> &Tensor { values[op.inputs[k]].as_ref().unwrap() };
    let mat = |k: usize| -> Result<Mat> { arg(k).to_mat() };

    Ok(match &op.kind {
        OpKind::Input => bindings
            .get(&op.name)
            .ok_or_else(|| anyhow!("unbound input {:?}", op.name))?
            .clone(),

        // ---- dense ----
        OpKind::MatMul => Tensor::from_mat(&mat(0)?.matmul(&mat(1)?)),
        // Oracle semantics for the sparse aggregation: densify the CSR
        // operand and run the dense matmul — the slow-but-obviously-right
        // path every SpMM kernel is property-tested against
        // (rust/tests/spmm_equivalence.rs). Dense lhs bindings pass
        // through `to_mat` unchanged.
        OpKind::SpMM => Tensor::from_mat(&mat(0)?.matmul(&mat(1)?)),
        OpKind::Transpose => Tensor::from_mat(&mat(0)?.transpose()),
        OpKind::Add => Tensor::from_mat(&broadcast_zip(&mat(0)?, &mat(1)?, |a, b| a + b)?),
        OpKind::Sub => Tensor::from_mat(&broadcast_zip(&mat(0)?, &mat(1)?, |a, b| a - b)?),
        OpKind::Mul => Tensor::from_mat(&broadcast_zip(&mat(0)?, &mat(1)?, |a, b| a * b)?),
        OpKind::Div => Tensor::from_mat(&broadcast_zip(&mat(0)?, &mat(1)?, |a, b| a / b)?),
        OpKind::Scale(c) => Tensor::from_mat(&mat(0)?.map(|x| x * c)),
        OpKind::AddConst(c) => Tensor::from_mat(&mat(0)?.map(|x| x + c)),
        OpKind::Relu => Tensor::from_mat(&mat(0)?.map(|x| x.max(0.0))),
        OpKind::LeakyRelu(s) => {
            let s = *s;
            Tensor::from_mat(&mat(0)?.map(move |x| if x > 0.0 { x } else { s * x }))
        }
        OpKind::Elu => Tensor::from_mat(&mat(0)?.map(|x| {
            if x > 0.0 {
                x
            } else {
                x.exp() - 1.0
            }
        })),
        OpKind::Exp => Tensor::from_mat(&mat(0)?.map(f32::exp)),
        OpKind::Sqrt => Tensor::from_mat(&mat(0)?.map(f32::sqrt)),
        OpKind::Rsqrt => Tensor::from_mat(&mat(0)?.map(|x| 1.0 / x.sqrt())),
        OpKind::Reciprocal => Tensor::from_mat(&mat(0)?.map(|x| 1.0 / x)),
        OpKind::BroadcastCol => {
            let a = mat(0)?;
            let n = op.shape[1];
            Tensor::from_mat(&Mat::from_fn(a.rows, n, |i, _| a[(i, 0)]))
        }
        OpKind::BroadcastRow => {
            let a = mat(0)?;
            let m = op.shape[0];
            Tensor::from_mat(&Mat::from_fn(m, a.cols, |_, j| a[(0, j)]))
        }
        OpKind::ReduceSumRows => {
            let a = mat(0)?;
            Tensor::from_mat(&Mat::from_fn(a.rows, 1, |i, _| {
                a.row(i).iter().sum()
            }))
        }
        OpKind::ReduceMaxRows => {
            let a = mat(0)?;
            Tensor::from_mat(&Mat::from_fn(a.rows, 1, |i, _| {
                a.row(i).iter().copied().fold(f32::NEG_INFINITY, f32::max)
            }))
        }
        OpKind::MaskedMaxPool => {
            let mask = mat(0)?;
            let h = mat(1)?;
            Tensor::from_mat(&Mat::from_fn(mask.rows, h.cols, |i, j| {
                let mut best = f32::NEG_INFINITY;
                for k in 0..mask.cols {
                    best = best.max(mask[(i, k)] * h[(k, j)]);
                }
                best
            }))
        }

        // ---- control-heavy ----
        OpKind::Greater => Tensor::from_mat(&broadcast_zip(&mat(0)?, &mat(1)?, |a, b| {
            if a > b {
                1.0
            } else {
                0.0
            }
        })?),
        OpKind::Select => {
            let cond = mat(0)?;
            let a = mat(1)?;
            let b = mat(2)?;
            if cond.shape() != a.shape() || a.shape() != b.shape() {
                bail!("select shape mismatch");
            }
            Tensor::from_mat(&Mat::from_fn(a.rows, a.cols, |i, j| {
                if cond[(i, j)] > 0.0 {
                    a[(i, j)]
                } else {
                    b[(i, j)]
                }
            }))
        }
        OpKind::Softmax => {
            let a = mat(0)?;
            let mut out = Mat::zeros(a.rows, a.cols);
            for i in 0..a.rows {
                let row = a.row(i);
                let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let mut denom = 0.0f32;
                // -inf rows (fully masked) → uniform-free zero row guard
                for (o, &x) in out.row_mut(i).iter_mut().zip(row) {
                    let e = if (x - m).is_nan() { 0.0 } else { (x - m).exp() };
                    *o = e;
                    denom += e;
                }
                if denom > 0.0 {
                    for o in out.row_mut(i) {
                        *o /= denom;
                    }
                }
            }
            Tensor::from_mat(&out)
        }
        OpKind::DegreesFromEdges => {
            let edges = edges_of(arg(0))?;
            let n = op.shape[0];
            let mut deg = Mat::filled(n, 1, 1.0); // self loop
            for (s, d) in edges {
                deg[(s, 0)] += 1.0;
                deg[(d, 0)] += 1.0;
            }
            Tensor::from_mat(&deg)
        }
        OpKind::AdjacencyFromEdges => {
            let edges = edges_of(arg(0))?;
            let n = op.shape[0];
            let mut a = Mat::zeros(n, n);
            for (s, d) in edges {
                a[(s, d)] = 1.0;
                a[(d, s)] = 1.0;
            }
            for i in 0..n {
                a[(i, i)] = 1.0;
            }
            Tensor::from_mat(&a)
        }
        OpKind::ScatterAddEdges => {
            let edges = edges_of(arg(0))?;
            let x = mat(1)?;
            let mut out = x.clone(); // self contribution
            for (s, d) in edges {
                for j in 0..x.cols {
                    out[(d, j)] += x[(s, j)];
                }
                for j in 0..x.cols {
                    out[(s, j)] += x[(d, j)];
                }
            }
            Tensor::from_mat(&out)
        }
        OpKind::NeighborGatherMax => {
            let (idx, w) = idx_of(arg(0))?;
            let h = mat(1)?;
            let n = h.rows;
            Tensor::from_mat(&Mat::from_fn(n, h.cols, |i, j| {
                let mut best = f32::NEG_INFINITY;
                for k in 0..w {
                    let t = idx[i * w + k] as usize;
                    if t < n {
                        best = best.max(h[(t, j)]);
                    }
                }
                if best.is_finite() {
                    best
                } else {
                    0.0
                }
            }))
        }
        OpKind::NeighborGatherMean => {
            let (idx, w) = idx_of(arg(0))?;
            let h = mat(1)?;
            let n = h.rows;
            Tensor::from_mat(&Mat::from_fn(n, h.cols, |i, j| {
                let mut sum = 0.0f32;
                let mut cnt = 0.0f32;
                for k in 0..w {
                    let t = idx[i * w + k] as usize;
                    if t < n {
                        sum += h[(t, j)];
                        cnt += 1.0;
                    }
                }
                sum / cnt.max(1.0)
            }))
        }

        // ---- QuantGr ----
        OpKind::Quantize { scale } => {
            let s = *scale;
            Tensor::from_mat(&mat(0)?.map(move |x| {
                (x / s).round().clamp(-127.0, 127.0)
            }))
        }
        OpKind::QMatMul { x_scale, w_scale } => {
            // operands already hold rounded int values in f32; accumulate
            // in f64 to model the INT32 accumulator exactly.
            let a = mat(0)?;
            let b = mat(1)?;
            if a.cols != b.rows {
                bail!("qmatmul dims");
            }
            let s = x_scale * w_scale;
            let mut out = Mat::zeros(a.rows, b.cols);
            for i in 0..a.rows {
                for j in 0..b.cols {
                    let mut acc = 0.0f64;
                    for k in 0..a.cols {
                        acc += a[(i, k)] as f64 * b[(k, j)] as f64;
                    }
                    out[(i, j)] = (acc as f32) * s;
                }
            }
            Tensor::from_mat(&out)
        }
    })
}

/// Elementwise combine with Add-style broadcasting ((m,n) op (m,n)|(1,n)|(m,1)).
fn broadcast_zip(a: &Mat, b: &Mat, f: impl Fn(f32, f32) -> f32) -> Result<Mat> {
    if a.shape() == b.shape() {
        return Ok(a.zip(b, f));
    }
    if b.rows == 1 && b.cols == a.cols {
        return Ok(Mat::from_fn(a.rows, a.cols, |i, j| f(a[(i, j)], b[(0, j)])));
    }
    if b.cols == 1 && b.rows == a.rows {
        return Ok(Mat::from_fn(a.rows, a.cols, |i, j| f(a[(i, j)], b[(i, 0)])));
    }
    bail!("broadcast mismatch {:?} vs {:?}", a.shape(), b.shape())
}

fn edges_of(t: &Tensor) -> Result<Vec<(usize, usize)>> {
    let data = t.as_i32()?;
    Ok(data
        .chunks_exact(2)
        .map(|c| (c[0] as usize, c[1] as usize))
        .collect())
}

fn idx_of(t: &Tensor) -> Result<(&[i32], usize)> {
    let w = *t
        .shape()
        .get(1)
        .ok_or_else(|| anyhow!("index tensor must be 2-D"))?;
    Ok((t.as_i32()?, w))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::Stage;
    use crate::tensor::DType;

    fn bind(pairs: &[(&str, Tensor)]) -> Bindings {
        pairs
            .iter()
            .map(|(k, v)| (k.to_string(), v.clone()))
            .collect()
    }

    #[test]
    fn matmul_add_relu_chain() {
        let mut g = OpGraph::new("chain");
        let x = g.input("x", &[2, 2], DType::F32, Stage::Compute);
        let w = g.input("w", &[2, 2], DType::F32, Stage::Compute);
        let b = g.input("b", &[1, 2], DType::F32, Stage::Compute);
        let mm = g.op(OpKind::MatMul, &[x, w], &[2, 2], Stage::Compute);
        let ad = g.op(OpKind::Add, &[mm, b], &[2, 2], Stage::Compute);
        let rl = g.op(OpKind::Relu, &[ad], &[2, 2], Stage::Compute);
        g.set_output(rl);
        let out = execute_mat(
            &g,
            &bind(&[
                ("x", Tensor::from_mat(&Mat::from_vec(2, 2, vec![1., 2., 3., 4.]))),
                ("w", Tensor::from_mat(&Mat::eye(2))),
                ("b", Tensor::from_mat(&Mat::from_vec(1, 2, vec![-2.5, 0.5]))),
            ]),
        )
        .unwrap();
        assert_eq!(out.data, vec![0.0, 2.5, 0.5, 4.5]);
    }

    #[test]
    fn unbound_input_errors() {
        let mut g = OpGraph::new("unbound");
        let x = g.input("x", &[1, 1], DType::F32, Stage::Compute);
        g.set_output(x);
        let err = execute(&g, &Bindings::new()).unwrap_err().to_string();
        assert!(err.contains("unbound"), "{err}");
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut g = OpGraph::new("sm");
        let x = g.input("x", &[2, 3], DType::F32, Stage::Compute);
        let s = g.op(OpKind::Softmax, &[x], &[2, 3], Stage::Compute);
        g.set_output(s);
        let out = execute_mat(
            &g,
            &bind(&[("x", Tensor::from_mat(&Mat::from_vec(2, 3, vec![1., 2., 3., -1e9, 0., -1e9])))]),
        )
        .unwrap();
        for i in 0..2 {
            let s: f32 = out.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        assert!(out[(1, 1)] > 0.999); // masked entries ~0
    }

    #[test]
    fn degrees_and_scatter_match_graph() {
        let edges = Tensor::I32 { shape: vec![2, 2], data: vec![0, 1, 1, 2] };
        let mut g = OpGraph::new("deg");
        let e = g.input("edges", &[2, 2], DType::I32, Stage::Preprocess);
        let d = g.op(OpKind::DegreesFromEdges, &[e], &[3, 1], Stage::Preprocess);
        g.set_output(d);
        let out = execute_mat(&g, &bind(&[("edges", edges.clone())])).unwrap();
        assert_eq!(out.data, vec![2.0, 3.0, 2.0]);

        let mut g2 = OpGraph::new("scatter");
        let e = g2.input("edges", &[2, 2], DType::I32, Stage::Preprocess);
        let x = g2.input("x", &[3, 1], DType::F32, Stage::Compute);
        let s = g2.op(OpKind::ScatterAddEdges, &[e, x], &[3, 1], Stage::Compute);
        g2.set_output(s);
        let out = execute_mat(
            &g2,
            &bind(&[
                ("edges", edges),
                ("x", Tensor::from_mat(&Mat::from_vec(3, 1, vec![1., 10., 100.]))),
            ]),
        )
        .unwrap();
        // node0: self 1 + nbr 10 = 11; node1: 10+1+100=111; node2: 100+10=110
        assert_eq!(out.data, vec![11.0, 111.0, 110.0]);
    }

    #[test]
    fn neighbor_gather_max_and_mean_sentinel_aware() {
        let idx = Tensor::I32 { shape: vec![3, 2], data: vec![0, 1, 1, 3, 3, 3] };
        let h = Tensor::from_mat(&Mat::from_vec(3, 1, vec![1., -5., 2.]));
        let mut g = OpGraph::new("gm");
        let i = g.input("idx", &[3, 2], DType::I32, Stage::Compute);
        let hh = g.input("h", &[3, 1], DType::F32, Stage::Compute);
        let mx = g.op(OpKind::NeighborGatherMax, &[i, hh], &[3, 1], Stage::Compute);
        g.set_output(mx);
        let out = execute_mat(&g, &bind(&[("idx", idx.clone()), ("h", h.clone())])).unwrap();
        assert_eq!(out.data, vec![1.0, -5.0, 0.0]); // row2 all-sentinel → 0

        let mut g2 = OpGraph::new("gmean");
        let i = g2.input("idx", &[3, 2], DType::I32, Stage::Compute);
        let hh = g2.input("h", &[3, 1], DType::F32, Stage::Compute);
        let mn = g2.op(OpKind::NeighborGatherMean, &[i, hh], &[3, 1], Stage::Compute);
        g2.set_output(mn);
        let out = execute_mat(&g2, &bind(&[("idx", idx), ("h", h)])).unwrap();
        assert_eq!(out.data, vec![-2.0, -5.0, 0.0]);
    }

    #[test]
    fn masked_maxpool_matches_definition() {
        let mask = Tensor::from_mat(&Mat::from_vec(2, 3, vec![1., 0., 1., 0., 0., 0.]));
        let h = Tensor::from_mat(&Mat::from_vec(3, 1, vec![4., 9., -2.]));
        let mut g = OpGraph::new("mp");
        let m = g.input("m", &[2, 3], DType::F32, Stage::Compute);
        let hh = g.input("h", &[3, 1], DType::F32, Stage::Compute);
        let p = g.op(OpKind::MaskedMaxPool, &[m, hh], &[2, 1], Stage::Compute);
        g.set_output(p);
        let out = execute_mat(&g, &bind(&[("m", mask), ("h", h)])).unwrap();
        // row0: max(1*4, 0*9, 1*-2) = 4; row1: max(0,0,0) = 0
        assert_eq!(out.data, vec![4.0, 0.0]);
    }

    #[test]
    fn quantize_rounds_and_clamps() {
        let mut g = OpGraph::new("q");
        let x = g.input("x", &[1, 3], DType::F32, Stage::Compute);
        let q = g.op(OpKind::Quantize { scale: 0.5 }, &[x], &[1, 3], Stage::Compute);
        g.set_output(q);
        let out = execute_mat(
            &g,
            &bind(&[("x", Tensor::from_mat(&Mat::from_vec(1, 3, vec![0.6, -100.0, 0.24])))]),
        )
        .unwrap();
        assert_eq!(out.data, vec![1.0, -127.0, 0.0]);
    }

    #[test]
    fn qmatmul_exact_large_k() {
        // 127·127·4096 exceeds f32's 2^24 integer range; the f64
        // accumulator must stay exact (mirrors the INT32 datapath).
        let k = 4096;
        let a = Mat::filled(1, k, 127.0);
        let b = Mat::filled(k, 1, 127.0);
        let mut g = OpGraph::new("qmm");
        let x = g.input("x", &[1, k], DType::F32, Stage::Compute);
        let w = g.input("w", &[k, 1], DType::F32, Stage::Compute);
        let y = g.op(
            OpKind::QMatMul { x_scale: 1.0, w_scale: 1.0 },
            &[x, w],
            &[1, 1],
            Stage::Compute,
        );
        g.set_output(y);
        let out = execute_mat(
            &g,
            &bind(&[("x", Tensor::from_mat(&a)), ("w", Tensor::from_mat(&b))]),
        )
        .unwrap();
        assert_eq!(out.data[0], (127.0f64 * 127.0 * k as f64) as f32);
    }

    #[test]
    fn select_and_greater() {
        let mut g = OpGraph::new("sel");
        let a = g.input("a", &[1, 3], DType::F32, Stage::Compute);
        let b = g.input("b", &[1, 3], DType::F32, Stage::Compute);
        let gt = g.op(OpKind::Greater, &[a, b], &[1, 3], Stage::Compute);
        let sel = g.op(OpKind::Select, &[gt, a, b], &[1, 3], Stage::Compute);
        g.set_output(sel);
        let out = execute_mat(
            &g,
            &bind(&[
                ("a", Tensor::from_mat(&Mat::from_vec(1, 3, vec![1., 5., 2.]))),
                ("b", Tensor::from_mat(&Mat::from_vec(1, 3, vec![3., 4., 2.]))),
            ]),
        )
        .unwrap();
        assert_eq!(out.data, vec![3.0, 5.0, 2.0]); // elementwise max via select
    }
}
