//! The planned execution engine: runs a compiled [`ExecPlan`] steady-state
//! with **zero allocations** — the run-many half of compile-once/run-many.
//!
//! A [`PlanInstance`] owns the mutable state a plan needs to execute:
//!
//! - the **buffer arena** (f32 + i8 slabs sized at compile time by the
//!   plan's liveness analysis — intermediates with disjoint live ranges
//!   share slabs),
//! - a handle to the in-tree [`WorkerPool`] that row-shards MatMul-shaped
//!   kernels across cores,
//! - **cached INT8 weights**: the first run converts each `QMatMul`
//!   weight input to `i8` (verifying the values are integral and in
//!   range); later runs fingerprint the binding and reuse the conversion,
//!   so the QuantGr path really multiplies `i8×i8 → i32` instead of the
//!   reference executor's rounded-f32 emulation.
//!
//! Numerics contract: a plan run matches [`crate::ops::exec::execute`]
//! within 1e-4 on every graph the oracle accepts (property-tested in
//! `rust/tests/plan_equivalence.rs`); fused chains and row-sharded
//! matmuls preserve the oracle's per-element accumulation order, so the
//! match is bitwise in practice.

pub mod kernels;
pub mod pool;

use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::ops::exec::Bindings;
use crate::ops::plan::{rc, Chain, ChainSrc, ExecPlan, FusedOp, PlanStep, StepKind, NO_SLOT};
use crate::ops::{OpGraph, OpId, OpKind};
use crate::tensor::{DensityHint, Mat, Tensor};
use crate::util::aligned::AlignedBuf;

pub use kernels::QOperand;
pub use pool::{par_rows, WorkerPool};

/// An unchecked operand view used inside fused-chain loops: raw pointer +
/// geometry + the compile-time broadcast position transform.
///
/// Raw (rather than a borrowed slice) so the reusable scratch vector can
/// live in the instance without self-borrow lifetimes. Invariant: views
/// are built and consumed inside a single step, while the source slabs
/// and bindings are alive and the output slab is detached.
#[derive(Clone, Copy)]
struct RawView {
    ptr: *const f32,
    len: usize,
    rows: usize,
    cols: usize,
    zero_i: bool,
    zero_j: bool,
}

// SAFETY: read-only view of data that outlives the step (see invariant
// above); used from pool lanes that the dispatching call joins.
unsafe impl Send for RawView {}
unsafe impl Sync for RawView {}

impl RawView {
    #[inline]
    fn at(&self, i: usize, j: usize) -> f32 {
        let r = if self.zero_i || self.rows == 1 { 0 } else { i };
        let c = if self.zero_j || self.cols == 1 { 0 } else { j };
        let idx = r * self.cols + c;
        debug_assert!(idx < self.len);
        // SAFETY: idx < len by shape validation; pointee alive (invariant).
        unsafe { *self.ptr.add(idx) }
    }
}

/// One fused-chain stage applied to a single element at (i, j).
#[inline]
fn eval_fused(s: FusedOp, v: f32, views: &[RawView], i: usize, j: usize) -> f32 {
    match s {
        FusedOp::Scale(c) => v * c,
        FusedOp::AddConst(c) => v + c,
        FusedOp::Relu => v.max(0.0),
        FusedOp::LeakyRelu(sl) => {
            if v > 0.0 {
                v
            } else {
                sl * v
            }
        }
        FusedOp::Exp => v.exp(),
        FusedOp::Quantize(sc) => (v / sc).round().clamp(-127.0, 127.0),
        FusedOp::Broadcast => v,
        FusedOp::Add(x) => v + views[1 + x as usize].at(i, j),
        FusedOp::Sub(x) => v - views[1 + x as usize].at(i, j),
        FusedOp::Mul(x) => v * views[1 + x as usize].at(i, j),
    }
}

/// Fused-chain interpreter over a row block, evaluated in 8-wide column
/// lanes: each stage is applied to a stack block of elements so the
/// arithmetic stages vectorize. Elements are independent and each lane
/// applies exactly the per-element math of [`eval_fused`], so results
/// are bitwise identical to the scalar interpreter.
fn chain_rows_simd(
    views: &[RawView],
    steps: &[FusedOp],
    cols: usize,
    r0: usize,
    r1: usize,
    outp: pool::SharedOut,
) {
    const JW: usize = 8;
    let mut v = [0.0f32; JW];
    for i in r0..r1 {
        let mut j = 0usize;
        while j < cols {
            let w = (cols - j).min(JW);
            for (l, vl) in v[..w].iter_mut().enumerate() {
                *vl = views[0].at(i, j + l);
            }
            for s in steps {
                match *s {
                    FusedOp::Scale(c) => {
                        for vl in &mut v[..w] {
                            *vl *= c;
                        }
                    }
                    FusedOp::AddConst(c) => {
                        for vl in &mut v[..w] {
                            *vl += c;
                        }
                    }
                    FusedOp::Relu => {
                        for vl in &mut v[..w] {
                            *vl = vl.max(0.0);
                        }
                    }
                    FusedOp::Add(x) => {
                        let vw = &views[1 + x as usize];
                        for (l, vl) in v[..w].iter_mut().enumerate() {
                            *vl += vw.at(i, j + l);
                        }
                    }
                    FusedOp::Sub(x) => {
                        let vw = &views[1 + x as usize];
                        for (l, vl) in v[..w].iter_mut().enumerate() {
                            *vl -= vw.at(i, j + l);
                        }
                    }
                    FusedOp::Mul(x) => {
                        let vw = &views[1 + x as usize];
                        for (l, vl) in v[..w].iter_mut().enumerate() {
                            *vl *= vw.at(i, j + l);
                        }
                    }
                    other => {
                        for (l, vl) in v[..w].iter_mut().enumerate() {
                            *vl = eval_fused(other, *vl, views, i, j + l);
                        }
                    }
                }
            }
            for (l, &vl) in v[..w].iter().enumerate() {
                // SAFETY: rows r0..r1 are exclusive to this lane.
                unsafe { *outp.0.add(i * cols + j + l) = vl };
            }
            j += w;
        }
    }
}

/// Cached i8 conversion of one QMatMul weight input.
struct CachedWeights {
    fingerprint: u64,
    data: Box<[i8]>,
    /// False when the f32 source was not integral-in-range: the kernel
    /// falls back to the oracle-exact f64-accumulation path.
    usable: bool,
}

/// Mutable execution state for one compiled plan. Create once, `run` many.
pub struct PlanInstance {
    plan: Arc<ExecPlan>,
    pool: Arc<WorkerPool>,
    slabs: Vec<AlignedBuf<f32>>,
    i8_slabs: Vec<AlignedBuf<i8>>,
    /// Per-op cached INT8 weights (QMatMul rhs only).
    w8: Vec<Option<CachedWeights>>,
    /// Reusable chain-operand scratch (capacity persists across runs).
    scratch: Vec<RawView>,
    /// Optional per-step telemetry profiler
    /// ([`crate::telemetry::Telemetry::plan_profiler`]). `None` (the
    /// default) keeps `run` on the proven zero-allocation, zero-clock
    /// path.
    profiler: Option<crate::telemetry::PlanProfiler>,
}

impl PlanInstance {
    pub fn new(plan: Arc<ExecPlan>, pool: Arc<WorkerPool>) -> PlanInstance {
        let slabs = plan.slab_elems.iter().map(|&e| AlignedBuf::zeroed(e)).collect();
        let i8_slabs =
            plan.i8_slab_elems.iter().map(|&e| AlignedBuf::zeroed(e)).collect();
        let w8 = (0..plan.graph.ops.len()).map(|_| None).collect();
        PlanInstance { plan, pool, slabs, i8_slabs, w8, scratch: Vec::new(), profiler: None }
    }

    /// True when every non-empty arena slab starts on an `align`-byte
    /// boundary — the SIMD-load contract `rust/tests/plan_alloc.rs` pins
    /// (slabs come from [`crate::util::aligned::AlignedBuf`]).
    pub fn arena_aligned(&self, align: usize) -> bool {
        self.slabs
            .iter()
            .filter(|s| !s.is_empty())
            .all(|s| s.as_ptr() as usize % align == 0)
            && self
                .i8_slabs
                .iter()
                .filter(|s| !s.is_empty())
                .all(|s| s.as_ptr() as usize % align == 0)
    }

    pub fn plan(&self) -> &Arc<ExecPlan> {
        &self.plan
    }

    /// Attach (or detach, with `None`) a telemetry profiler. Enabled,
    /// each step is wall-timed and folded into the shard's calibration
    /// sink after the run; detached, `run` takes the original
    /// branch-only path.
    pub fn attach_profiler(&mut self, profiler: Option<crate::telemetry::PlanProfiler>) {
        self.profiler = profiler;
    }

    /// Execute every step against `bindings`. Steady-state (same plan,
    /// same binding storage) this performs no heap allocation.
    pub fn run(&mut self, bindings: &Bindings) -> Result<()> {
        let plan = Arc::clone(&self.plan);
        let profiling = self.profiler.is_some();
        for si in 0..plan.steps.len() {
            let t0 = if profiling { Some(std::time::Instant::now()) } else { None };
            self.exec_step(&plan, &plan.steps[si], bindings).with_context(|| {
                let op = &plan.graph.ops[plan.steps[si].op];
                format!(
                    "{} plan step {si} (op#{} {})",
                    plan.graph.name,
                    plan.steps[si].op,
                    op.kind.name()
                )
            })?;
            if let (Some(t0), Some(p)) = (t0, self.profiler.as_mut()) {
                p.observe(si, t0.elapsed().as_secs_f64() * 1e6);
            }
        }
        if let Some(p) = self.profiler.as_mut() {
            p.flush();
        }
        Ok(())
    }

    /// Zero-copy view of output `idx`.
    pub fn output_view(&self, idx: usize) -> Result<(&[f32], usize, usize)> {
        let id = *self
            .plan
            .graph
            .outputs
            .get(idx)
            .ok_or_else(|| anyhow!("output {idx} out of range"))?;
        let (r, c) = rc(&self.plan.graph.ops[id].shape)?;
        let slot = self.plan.slot[id];
        if slot == NO_SLOT {
            bail!("output op#{id} has no f32 slab");
        }
        Ok((&self.slabs[slot][..r * c], r, c))
    }

    /// Output `idx` copied into a fresh matrix.
    pub fn output_mat(&self, idx: usize) -> Result<Mat> {
        let (d, r, c) = self.output_view(idx)?;
        Ok(Mat::from_vec(r, c, d.to_vec()))
    }

    /// All outputs as matrices.
    pub fn outputs(&self) -> Result<Vec<Mat>> {
        (0..self.plan.graph.outputs.len())
            .map(|i| self.output_mat(i))
            .collect()
    }

    // ------------------------------------------------------------------
    // step dispatch
    // ------------------------------------------------------------------

    fn exec_step(&mut self, plan: &ExecPlan, step: &PlanStep, b: &Bindings) -> Result<()> {
        match &step.kind {
            StepKind::Chain(ch) => self.run_chain(plan, step.op, ch, b),
            StepKind::QuantizeI8 { scale } => self.run_quantize_i8(plan, step.op, *scale, b),
            StepKind::Kernel => {
                if matches!(plan.graph.ops[step.op].kind, OpKind::QMatMul { .. }) {
                    self.ensure_w8(plan, step.op, b)?;
                }
                self.run_kernel(plan, step.op, b)
            }
        }
    }

    /// Resolve an op's f32 value (binding for inputs, arena slab else).
    fn f32_of<'a>(
        &'a self,
        plan: &'a ExecPlan,
        id: OpId,
        b: &'a Bindings,
    ) -> Result<(&'a [f32], usize, usize)> {
        let op = &plan.graph.ops[id];
        let (r, c) = rc(&op.shape)?;
        if op.kind == OpKind::Input {
            let t = b
                .get(&op.name)
                .ok_or_else(|| anyhow!("unbound input {:?}", op.name))?;
            let d = match t {
                Tensor::F32 { data, .. } => data,
                Tensor::Csr { .. } => bail!(
                    "input {:?}: bound as CSR but consumed densely \
                     (only SpMM reads sparse operands)",
                    op.name
                ),
                other => bail!(
                    "input {:?}: expected f32 binding, got {:?}",
                    op.name,
                    other.dtype()
                ),
            };
            if d.len() != r * c {
                bail!(
                    "input {:?}: binding has {} elements, graph expects {}x{}",
                    op.name,
                    d.len(),
                    r,
                    c
                );
            }
            Ok((&d[..], r, c))
        } else {
            let slot = plan.slot[id];
            if slot == NO_SLOT {
                bail!("op#{id} has no materialized f32 value");
            }
            Ok((&self.slabs[slot][..r * c], r, c))
        }
    }

    /// Resolve an i32 index binding (graph inputs only).
    fn i32_of<'a>(
        &self,
        plan: &ExecPlan,
        id: OpId,
        b: &'a Bindings,
    ) -> Result<(&'a [i32], usize, usize)> {
        let op = &plan.graph.ops[id];
        let (r, c) = rc(&op.shape)?;
        if op.kind != OpKind::Input {
            bail!("computed index tensors unsupported");
        }
        let t = b
            .get(&op.name)
            .ok_or_else(|| anyhow!("unbound input {:?}", op.name))?;
        let d = t.as_i32()?;
        if d.len() != r * c {
            bail!("input {:?}: {} elements vs {}x{}", op.name, d.len(), r, c);
        }
        Ok((d, r, c))
    }

    fn raw_view(&self, plan: &ExecPlan, src: &ChainSrc, b: &Bindings) -> Result<RawView> {
        let (d, r, c) = self.f32_of(plan, src.op, b)?;
        Ok(RawView {
            ptr: d.as_ptr(),
            len: d.len(),
            rows: r,
            cols: c,
            zero_i: src.pos.zero_i,
            zero_j: src.pos.zero_j,
        })
    }

    // ------------------------------------------------------------------
    // fused chains
    // ------------------------------------------------------------------

    fn run_chain(&mut self, plan: &ExecPlan, id: OpId, ch: &Chain, b: &Bindings) -> Result<()> {
        let mut scratch = std::mem::take(&mut self.scratch);
        scratch.clear();
        scratch.push(self.raw_view(plan, &ch.head, b)?);
        for a in &ch.aux {
            scratch.push(self.raw_view(plan, a, b)?);
        }
        let slot = plan.slot[id];
        let mut out = std::mem::take(&mut self.slabs[slot]);
        // the chain loop writes through an unchecked raw pointer: the slab
        // must be big enough even if a previous panic left state behind
        assert!(
            out.len() >= ch.rows * ch.cols,
            "arena slab {slot} too small for chain output"
        );
        let simd = plan.kernels.simd.enabled();
        let eval = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let (rows, cols) = (ch.rows, ch.cols);
            let steps: &[FusedOp] = &ch.steps;
            let views: &[RawView] = &scratch;
            let outp = pool::SharedOut(out.as_mut_ptr());
            par_rows(&self.pool, rows, 32, &|r0, r1| {
                if simd {
                    chain_rows_simd(views, steps, cols, r0, r1, outp);
                } else {
                    for i in r0..r1 {
                        for j in 0..cols {
                            let mut v = views[0].at(i, j);
                            for s in steps {
                                v = eval_fused(*s, v, views, i, j);
                            }
                            // SAFETY: rows r0..r1 are exclusive to this lane.
                            unsafe { *outp.0.add(i * cols + j) = v };
                        }
                    }
                }
            });
        }));
        // restore the slab/scratch even when a lane panicked, so a caller
        // that catches the panic finds the instance structurally intact
        self.slabs[slot] = out;
        self.scratch = scratch;
        if let Err(payload) = eval {
            std::panic::resume_unwind(payload);
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // INT8
    // ------------------------------------------------------------------

    fn run_quantize_i8(
        &mut self,
        plan: &ExecPlan,
        id: OpId,
        scale: f32,
        b: &Bindings,
    ) -> Result<()> {
        let slot = plan.i8_slot[id];
        let mut out = std::mem::take(&mut self.i8_slabs[slot]);
        let res = (|| -> Result<()> {
            let src = plan.graph.ops[id].inputs[0];
            let (d, r, c) = self.f32_of(plan, src, b)?;
            let ob = &mut out[..r * c];
            for (o, &x) in ob.iter_mut().zip(d) {
                *o = (x / scale).round().clamp(-127.0, 127.0) as i8;
            }
            Ok(())
        })();
        self.i8_slabs[slot] = out;
        res
    }

    /// Prepare/refresh the cached INT8 conversion of a QMatMul's weight
    /// input. Fingerprinted so rebinding the same tensor is free.
    fn ensure_w8(&mut self, plan: &ExecPlan, id: OpId, b: &Bindings) -> Result<()> {
        let rhs_id = plan.graph.ops[id].inputs[1];
        let rop = &plan.graph.ops[rhs_id];
        if rop.kind != OpKind::Input {
            self.w8[id] = None;
            return Ok(());
        }
        let (wr, wc) = rc(&rop.shape)?;
        let t = b
            .get(&rop.name)
            .ok_or_else(|| anyhow!("unbound input {:?}", rop.name))?;
        if t.num_elements() != wr * wc {
            bail!(
                "QMatMul weights {:?}: {} elements, graph expects {}x{}",
                rop.name,
                t.num_elements(),
                wr,
                wc
            );
        }
        match t {
            Tensor::I8 { data, .. } => {
                let fp = fingerprint_i8(data);
                if cached_fp(&self.w8[id]) == Some(fp) {
                    return Ok(());
                }
                self.w8[id] = Some(CachedWeights {
                    fingerprint: fp,
                    data: data.clone().into_boxed_slice(),
                    usable: true,
                });
            }
            Tensor::F32 { data, .. } => {
                let fp = fingerprint_f32(data);
                if cached_fp(&self.w8[id]) == Some(fp) {
                    return Ok(());
                }
                let usable = data
                    .iter()
                    .all(|&v| v.fract() == 0.0 && (-127.0..=127.0).contains(&v));
                let conv: Box<[i8]> = if usable {
                    data.iter().map(|&v| v as i8).collect()
                } else {
                    Vec::new().into_boxed_slice()
                };
                self.w8[id] =
                    Some(CachedWeights { fingerprint: fp, data: conv, usable });
            }
            other => bail!(
                "QMatMul weights {:?} must be f32 or i8, got {:?}",
                rop.name,
                other.dtype()
            ),
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // dedicated kernels
    // ------------------------------------------------------------------

    fn run_kernel(&mut self, plan: &ExecPlan, id: OpId, b: &Bindings) -> Result<()> {
        let op = &plan.graph.ops[id];
        let (rows, cols) = rc(&op.shape)?;
        let n_out = rows * cols;
        let slot = plan.slot[id];
        let mut out_slab = std::mem::take(&mut self.slabs[slot]);
        let res = (|| -> Result<()> {
            let out = &mut out_slab[..n_out];
            let pool = &self.pool;
            let simd = plan.kernels.simd.enabled();
            match &op.kind {
                OpKind::MatMul => {
                    let (a, m, k) = self.f32_of(plan, op.inputs[0], b)?;
                    let (w, _, nn) = self.f32_of(plan, op.inputs[1], b)?;
                    kernels::matmul_with(
                        pool, a, m, k, w, nn, out, plan.density_hint[id], simd,
                    );
                }
                OpKind::SpMM => {
                    let (h, hr, nn) = self.f32_of(plan, op.inputs[1], b)?;
                    let lop = &plan.graph.ops[op.inputs[0]];
                    let (lr, lc) = rc(&lop.shape)?;
                    if (lr, lc) != (rows, hr) {
                        bail!("spmm operand shape mismatch");
                    }
                    let t = b.get(&lop.name).ok_or_else(|| {
                        anyhow!("unbound input {:?}", lop.name)
                    })?;
                    match t {
                        Tensor::Csr { mat, .. } => {
                            if (mat.rows, mat.cols) != (lr, lc) {
                                bail!(
                                    "input {:?}: CSR is {}x{}, graph expects {}x{}",
                                    lop.name, mat.rows, mat.cols, lr, lc
                                );
                            }
                            kernels::spmm_with(
                                pool, &mat.indptr, &mat.indices, &mat.values,
                                rows, h, nn, out, plan.kernels.degree_bins, simd,
                            );
                        }
                        // dense fallback: above the density threshold the
                        // caller may bind the dense mask to the same plan
                        Tensor::F32 { data, .. } => {
                            if data.len() != lr * lc {
                                bail!(
                                    "input {:?}: dense binding has {} elements, \
                                     graph expects {}x{}",
                                    lop.name, data.len(), lr, lc
                                );
                            }
                            kernels::matmul_with(
                                pool, data, rows, hr, h, nn, out,
                                DensityHint::Sample, simd,
                            );
                        }
                        other => bail!(
                            "input {:?}: SpMM operand must be CSR or f32, got {:?}",
                            lop.name,
                            other.dtype()
                        ),
                    }
                }
                OpKind::QMatMul { x_scale, w_scale } => {
                    let s = x_scale * w_scale;
                    let lhs_id = op.inputs[0];
                    let rhs_id = op.inputs[1];
                    let (m, k) = rc(&plan.graph.ops[lhs_id].shape)?;
                    let (_, nn) = rc(&plan.graph.ops[rhs_id].shape)?;
                    let lhs_slot = plan.i8_slot[lhs_id];
                    let w8_ok = matches!(&self.w8[id], Some(cw) if cw.usable);
                    if lhs_slot != NO_SLOT && w8_ok {
                        let x8 = &self.i8_slabs[lhs_slot][..m * k];
                        let cw = self.w8[id].as_ref().unwrap();
                        kernels::qmatmul_i8_with(pool, x8, &cw.data, m, k, nn, s, out, simd);
                    } else {
                        let lhs = if lhs_slot != NO_SLOT {
                            QOperand::I8(&self.i8_slabs[lhs_slot][..m * k])
                        } else {
                            QOperand::F32(self.f32_of(plan, lhs_id, b)?.0)
                        };
                        let rhs = if w8_ok {
                            QOperand::I8(&self.w8[id].as_ref().unwrap().data)
                        } else {
                            QOperand::F32(self.f32_of(plan, rhs_id, b)?.0)
                        };
                        kernels::qmatmul_acc64(pool, &lhs, &rhs, m, k, nn, s, out);
                    }
                }
                OpKind::Transpose => {
                    let (a, r, c) = self.f32_of(plan, op.inputs[0], b)?;
                    kernels::transpose(a, r, c, out);
                }
                OpKind::Div => {
                    let (a, ar, ac) = self.f32_of(plan, op.inputs[0], b)?;
                    let (w, br, bc) = self.f32_of(plan, op.inputs[1], b)?;
                    kernels::zip_broadcast(a, ar, ac, w, br, bc, out, |x, y| x / y);
                }
                OpKind::Greater => {
                    let (a, ar, ac) = self.f32_of(plan, op.inputs[0], b)?;
                    let (w, br, bc) = self.f32_of(plan, op.inputs[1], b)?;
                    kernels::zip_broadcast(a, ar, ac, w, br, bc, out, |x, y| {
                        if x > y {
                            1.0
                        } else {
                            0.0
                        }
                    });
                }
                OpKind::Elu => {
                    let (a, _, _) = self.f32_of(plan, op.inputs[0], b)?;
                    kernels::map_unary(a, out, |x| {
                        if x > 0.0 {
                            x
                        } else {
                            x.exp() - 1.0
                        }
                    });
                }
                OpKind::Sqrt => {
                    let (a, _, _) = self.f32_of(plan, op.inputs[0], b)?;
                    kernels::map_unary(a, out, f32::sqrt);
                }
                OpKind::Rsqrt => {
                    let (a, _, _) = self.f32_of(plan, op.inputs[0], b)?;
                    kernels::map_unary(a, out, |x| 1.0 / x.sqrt());
                }
                OpKind::Reciprocal => {
                    let (a, _, _) = self.f32_of(plan, op.inputs[0], b)?;
                    kernels::map_unary(a, out, |x| 1.0 / x);
                }
                OpKind::ReduceSumRows => {
                    let (a, r, c) = self.f32_of(plan, op.inputs[0], b)?;
                    kernels::reduce_sum_rows(a, r, c, out);
                }
                OpKind::ReduceMaxRows => {
                    let (a, r, c) = self.f32_of(plan, op.inputs[0], b)?;
                    kernels::reduce_max_rows(a, r, c, out);
                }
                OpKind::Softmax => {
                    let (a, r, c) = self.f32_of(plan, op.inputs[0], b)?;
                    kernels::softmax(a, r, c, out);
                }
                OpKind::MaskedMaxPool => {
                    let (mask, m, n) = self.f32_of(plan, op.inputs[0], b)?;
                    let (h, _, f) = self.f32_of(plan, op.inputs[1], b)?;
                    kernels::masked_max_pool(pool, mask, m, n, h, f, out);
                }
                OpKind::Select => {
                    let (cond, cr, cc) = self.f32_of(plan, op.inputs[0], b)?;
                    let (av, ar, ac) = self.f32_of(plan, op.inputs[1], b)?;
                    let (bv, br, bc) = self.f32_of(plan, op.inputs[2], b)?;
                    if (cr, cc) != (ar, ac) || (ar, ac) != (br, bc) {
                        bail!("select shape mismatch");
                    }
                    kernels::select(cond, av, bv, out);
                }
                OpKind::DegreesFromEdges => {
                    let (e, _, _) = self.i32_of(plan, op.inputs[0], b)?;
                    kernels::degrees_from_edges(e, rows, out);
                }
                OpKind::AdjacencyFromEdges => {
                    let (e, _, _) = self.i32_of(plan, op.inputs[0], b)?;
                    if cols != rows {
                        bail!("adjacency output must be square");
                    }
                    kernels::adjacency_from_edges(e, rows, out);
                }
                OpKind::ScatterAddEdges => {
                    let (e, _, _) = self.i32_of(plan, op.inputs[0], b)?;
                    let (x, xn, xf) = self.f32_of(plan, op.inputs[1], b)?;
                    if (xn, xf) != (rows, cols) {
                        bail!("scatter output shape mismatch");
                    }
                    kernels::scatter_add_edges(e, x, xn, xf, out);
                }
                OpKind::NeighborGatherMax => {
                    let (idx, _, w) = self.i32_of(plan, op.inputs[0], b)?;
                    let (h, hn, hf) = self.f32_of(plan, op.inputs[1], b)?;
                    kernels::neighbor_gather_max(idx, w, h, hn, hf, out);
                }
                OpKind::NeighborGatherMean => {
                    let (idx, _, w) = self.i32_of(plan, op.inputs[0], b)?;
                    let (h, hn, hf) = self.f32_of(plan, op.inputs[1], b)?;
                    kernels::neighbor_gather_mean(idx, w, h, hn, hf, out);
                }
                other => bail!("op {} has no planned kernel", other.name()),
            }
            Ok(())
        })();
        self.slabs[slot] = out_slab;
        res
    }
}

fn cached_fp(c: &Option<CachedWeights>) -> Option<u64> {
    c.as_ref().map(|w| w.fingerprint)
}

/// Content fingerprint over **every** element (FNV-1a of the raw bits):
/// weight tensors are small next to the matmuls that consume them, and a
/// sampled hash could miss a rebind that reuses the old allocation with
/// values changed only at unprobed indices — silently serving stale
/// weights. Full hashing is a few µs and allocation-free.
fn fingerprint_f32(d: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64 ^ ((d.len() as u64) << 1);
    for v in d {
        h = (h ^ v.to_bits() as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

fn fingerprint_i8(d: &[i8]) -> u64 {
    let mut h = 0x8422_2325_cbf2_9ce4u64 ^ ((d.len() as u64) << 1);
    for &v in d {
        h = (h ^ v as u8 as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// One-shot convenience: compile `g`, run it serially, return all outputs.
pub fn run_graph(g: &OpGraph, bindings: &Bindings) -> Result<Vec<Mat>> {
    let plan = Arc::new(ExecPlan::compile(g)?);
    let mut inst = PlanInstance::new(plan, Arc::new(WorkerPool::serial()));
    inst.run(bindings)?;
    inst.outputs()
}

/// One-shot convenience for single-output graphs.
pub fn run_graph_mat(g: &OpGraph, bindings: &Bindings) -> Result<Mat> {
    let mut outs = run_graph(g, bindings)?;
    if outs.is_empty() {
        bail!("graph has no outputs");
    }
    Ok(outs.remove(0))
}

// ---------------------------------------------------------------------------
// Tiled subset execution — the gather/scatter partial-execution path
// ---------------------------------------------------------------------------

/// One compiled tile: a [`PlanInstance`] at a fixed padded `(rows, ring)`
/// geometry plus persistent bindings that are mutated **in place** — the
/// caller gathers a node subset into [`Tile::binding_mut`] buffers, runs,
/// and scatters [`Tile::output`] rows back out. Warm tiles execute with
/// no steady-state allocation, exactly like full plans.
pub struct Tile {
    instance: PlanInstance,
    bindings: Bindings,
    /// Padded row capacity (frontier tile height).
    pub rows: usize,
    /// Padded ring capacity (input-subset height / mask width).
    pub ring: usize,
}

impl Tile {
    /// Mutable storage of a named f32 binding (gather target).
    pub fn binding_mut(&mut self, name: &str) -> Result<&mut [f32]> {
        match self.bindings.get_mut(name) {
            Some(Tensor::F32 { data, .. }) => Ok(&mut data[..]),
            Some(other) => bail!("tile binding {name:?} is {:?}, not f32", other.dtype()),
            None => bail!("tile has no binding {name:?}"),
        }
    }

    /// Execute the tile's plan over the current bindings.
    pub fn run(&mut self) -> Result<()> {
        self.instance.run(&self.bindings)
    }

    /// Zero-copy view of the tile output (scatter source).
    pub fn output(&self) -> Result<(&[f32], usize, usize)> {
        self.instance.output_view(0)
    }
}

/// Compile-once/run-many execution of a plan family over **node
/// subsets**: tile geometries are bucketed to powers of two (clamped to
/// the graph capacity, so the full-recompute tile is exact), each bucket
/// compiled once via the `build` callback and cached with its
/// [`PlanInstance`] + bindings. Subset sizes that land in the same bucket
/// reuse the warm tile — NodePad's stable-shape trick applied to
/// frontier execution.
pub struct TileRunner {
    pool: Arc<WorkerPool>,
    build: Box<dyn Fn(usize, usize) -> OpGraph + Send>,
    /// Bindings cloned into every new tile (weights, biases).
    statics: Bindings,
    /// Smallest bucket (avoids a tile per tiny frontier size).
    min: usize,
    /// Geometry clamp: row/ring buckets never exceed these.
    max_rows: usize,
    max_ring: usize,
    tiles: std::collections::BTreeMap<(usize, usize), Tile>,
    /// When set, every tile's [`PlanInstance`] gets a profiler feeding
    /// this hub's per-shard calibration sink.
    telemetry: Option<(Arc<crate::telemetry::Telemetry>, usize)>,
    /// Kernel knobs every tile plan is compiled with — tiles route
    /// through the same microkernel dispatch as full plans.
    kernels: crate::ops::plan::KernelConfig,
}

impl TileRunner {
    pub fn new(
        pool: Arc<WorkerPool>,
        min: usize,
        max_rows: usize,
        max_ring: usize,
        statics: Bindings,
        build: impl Fn(usize, usize) -> OpGraph + Send + 'static,
    ) -> TileRunner {
        TileRunner {
            pool,
            build: Box::new(build),
            statics,
            min: min.max(1),
            max_rows,
            max_ring,
            tiles: std::collections::BTreeMap::new(),
            telemetry: None,
            kernels: crate::ops::plan::KernelConfig::default(),
        }
    }

    /// Set the kernel knobs future tiles compile with (SIMD dispatch,
    /// degree bins). Call before the first [`TileRunner::tile`];
    /// already-compiled tiles keep their plan.
    pub fn set_kernels(&mut self, kernels: crate::ops::plan::KernelConfig) {
        self.kernels = kernels;
    }

    /// Route per-step profiling of every tile (already-compiled and
    /// future) into `telemetry`'s sink for `shard`. A disabled hub hands
    /// out `None` profilers, so this is safe to call unconditionally.
    pub fn set_telemetry(&mut self, telemetry: Arc<crate::telemetry::Telemetry>, shard: usize) {
        for tile in self.tiles.values_mut() {
            let plan = Arc::clone(tile.instance.plan());
            tile.instance.attach_profiler(telemetry.plan_profiler(shard, &plan));
        }
        self.telemetry = Some((telemetry, shard));
    }

    /// The padded geometry a `(rows, ring)` subset executes at.
    pub fn bucket(&self, rows: usize, ring: usize) -> (usize, usize) {
        let up = |x: usize, cap: usize| -> usize {
            x.max(self.min).next_power_of_two().min(cap).max(x)
        };
        (up(rows, self.max_rows), up(ring, self.max_ring))
    }

    /// Tiles compiled so far (compile-once observability).
    pub fn compiled_tiles(&self) -> usize {
        self.tiles.len()
    }

    /// The warm tile for a subset geometry, compiling it on first use.
    /// New tiles start with zeroed dynamic bindings plus the statics.
    pub fn tile(&mut self, rows: usize, ring: usize) -> Result<&mut Tile> {
        let key = self.bucket(rows, ring);
        if !self.tiles.contains_key(&key) {
            let graph = (self.build)(key.0, key.1);
            let plan = Arc::new(ExecPlan::compile_with(&graph, self.kernels)?);
            let mut bindings = self.statics.clone();
            for op in &plan.graph.ops {
                if op.kind == OpKind::Input && !bindings.contains_key(&op.name) {
                    let (r, c) = rc(&op.shape)?;
                    bindings.insert(
                        op.name.clone(),
                        Tensor::F32 { shape: vec![r, c], data: vec![0.0; r * c] },
                    );
                }
            }
            let mut instance = PlanInstance::new(Arc::clone(&plan), Arc::clone(&self.pool));
            if let Some((tel, shard)) = &self.telemetry {
                instance.attach_profiler(tel.plan_profiler(*shard, &plan));
            }
            self.tiles.insert(
                key,
                Tile { instance, bindings, rows: key.0, ring: key.1 },
            );
        }
        Ok(self.tiles.get_mut(&key).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::build::{self, GnnDims, QuantScales};
    use crate::ops::exec;
    use crate::ops::Stage;
    use crate::tensor::DType;
    use crate::util::Rng;
    use std::collections::BTreeMap;

    fn dims() -> GnnDims {
        GnnDims { n: 18, m: 30, f: 10, hidden: 6, classes: 4, k: 5, layers: 2 }
    }

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_fn(r, c, |_, _| (rng.f64() * 0.8 - 0.4) as f32)
    }

    fn gcn_bindings(seed: u64) -> Bindings {
        let d = dims();
        let ds = crate::graph::datasets::synthesize("eng", d.n, d.m, d.classes, d.f, seed);
        let mut rng = Rng::new(seed ^ 0x51);
        let mut b: Bindings = BTreeMap::new();
        b.insert("x".into(), Tensor::from_mat(&ds.features));
        b.insert("norm".into(), Tensor::from_mat(&ds.graph.norm_adjacency(d.n)));
        b.insert("w1".into(), Tensor::from_mat(&rand_mat(&mut rng, d.f, d.hidden)));
        b.insert("b1".into(), Tensor::from_mat(&rand_mat(&mut rng, 1, d.hidden)));
        b.insert("w2".into(), Tensor::from_mat(&rand_mat(&mut rng, d.hidden, d.classes)));
        b.insert("b2".into(), Tensor::from_mat(&rand_mat(&mut rng, 1, d.classes)));
        b
    }

    #[test]
    fn plan_matches_reference_on_gcn() {
        let g = build::gcn_stagr(dims(), "stagr");
        let b = gcn_bindings(3);
        let want = exec::execute_mat(&g, &b).unwrap();
        let got = run_graph_mat(&g, &b).unwrap();
        assert!(
            want.max_abs_diff(&got) < 1e-4,
            "diff {}",
            want.max_abs_diff(&got)
        );
    }

    #[test]
    fn sparse_plan_matches_reference_and_dense_fallback() {
        use crate::ops::build::Aggregation;
        use crate::tensor::CsrMat;
        let g_dense = build::gcn_stagr(dims(), "stagr");
        let g_sparse = build::gcn_stagr_with(dims(), "stagr", Aggregation::Sparse);
        let b = gcn_bindings(19);
        let mut bs = b.clone();
        let norm = b["norm"].to_mat().unwrap();
        bs.insert("norm".into(), Tensor::from_csr(CsrMat::from_dense(&norm)));
        let want = exec::execute_mat(&g_dense, &b).unwrap();
        // CSR binding through the planned SpMM kernel
        let got = run_graph_mat(&g_sparse, &bs).unwrap();
        assert!(want.max_abs_diff(&got) < 1e-4, "{}", want.max_abs_diff(&got));
        // dense binding on the same sparse plan: the threshold fallback
        let fb = run_graph_mat(&g_sparse, &b).unwrap();
        assert_eq!(fb, got, "dense fallback must agree bitwise");
        // a CSR binding consumed densely is a clean error, not garbage
        let err = run_graph(&g_dense, &bs).unwrap_err().to_string();
        assert!(err.contains("CSR"), "{err}");
    }

    #[test]
    fn warm_instance_is_deterministic() {
        let g = build::gcn_stagr(dims(), "stagr");
        let b = gcn_bindings(7);
        let plan = Arc::new(ExecPlan::compile(&g).unwrap());
        let mut inst = PlanInstance::new(plan, Arc::new(WorkerPool::new(3)));
        inst.run(&b).unwrap();
        let first = inst.output_mat(0).unwrap();
        for _ in 0..3 {
            inst.run(&b).unwrap();
            assert_eq!(inst.output_mat(0).unwrap(), first, "stale-arena drift");
        }
    }

    #[test]
    fn parallel_and_serial_instances_agree() {
        let g = build::gcn_stagr(dims(), "stagr");
        let b = gcn_bindings(11);
        let plan = Arc::new(ExecPlan::compile(&g).unwrap());
        let mut serial = PlanInstance::new(Arc::clone(&plan), Arc::new(WorkerPool::serial()));
        let mut par = PlanInstance::new(plan, Arc::new(WorkerPool::new(4)));
        serial.run(&b).unwrap();
        par.run(&b).unwrap();
        assert_eq!(serial.output_mat(0).unwrap(), par.output_mat(0).unwrap());
    }

    #[test]
    fn simd_off_plan_matches_default_bitwise() {
        // the scalar-fallback configuration is the oracle path: a plan
        // compiled with SIMD off must agree exactly with the default
        use crate::ops::plan::{KernelConfig, SimdMode};
        let g = build::gcn_stagr(dims(), "stagr");
        let b = gcn_bindings(23);
        let pool = Arc::new(WorkerPool::new(3));
        let default_plan = Arc::new(ExecPlan::compile(&g).unwrap());
        let scalar_plan = Arc::new(
            ExecPlan::compile_with(
                &g,
                KernelConfig { simd: SimdMode::Off, ..KernelConfig::default() },
            )
            .unwrap(),
        );
        let mut simd = PlanInstance::new(default_plan, Arc::clone(&pool));
        let mut scalar = PlanInstance::new(scalar_plan, pool);
        simd.run(&b).unwrap();
        scalar.run(&b).unwrap();
        assert_eq!(simd.output_mat(0).unwrap(), scalar.output_mat(0).unwrap());
        // the arena behind both instances is slab-aligned for SIMD loads
        assert!(simd.arena_aligned(crate::util::aligned::SLAB_ALIGN));
    }

    #[test]
    fn int8_weights_binding_matches_f32_integral() {
        // QuantGr: binding real Tensor::I8 weights must equal binding the
        // same values as rounded f32 (the oracle-compatible encoding)
        let d = dims();
        let g = build::gcn_quant(d, QuantScales::default());
        let mut b = gcn_bindings(13);
        let mut rng = Rng::new(99);
        let w1q: Vec<i8> = (0..d.f * d.hidden)
            .map(|_| (rng.usize(255) as i32 - 127) as i8)
            .collect();
        let w2q: Vec<i8> = (0..d.hidden * d.classes)
            .map(|_| (rng.usize(255) as i32 - 127) as i8)
            .collect();
        let mut b_f32 = b.clone();
        b_f32.insert(
            "w1q".into(),
            Tensor::from_mat(&Mat::from_vec(
                d.f,
                d.hidden,
                w1q.iter().map(|&v| v as f32).collect(),
            )),
        );
        b_f32.insert(
            "w2q".into(),
            Tensor::from_mat(&Mat::from_vec(
                d.hidden,
                d.classes,
                w2q.iter().map(|&v| v as f32).collect(),
            )),
        );
        b.insert("w1q".into(), Tensor::I8 { shape: vec![d.f, d.hidden], data: w1q });
        b.insert("w2q".into(), Tensor::I8 { shape: vec![d.hidden, d.classes], data: w2q });

        let via_f32 = run_graph_mat(&g, &b_f32).unwrap();
        let via_i8 = run_graph_mat(&g, &b).unwrap();
        assert!(via_f32.max_abs_diff(&via_i8) < 1e-5);
        // and both agree with the oracle on the f32 encoding
        let oracle = exec::execute_mat(&g, &b_f32).unwrap();
        assert!(oracle.max_abs_diff(&via_f32) < 1e-4);
    }

    #[test]
    fn non_integral_weights_fall_back_to_oracle_path() {
        let d = dims();
        let g = build::gcn_quant(d, QuantScales::default());
        let mut b = gcn_bindings(17);
        let mut rng = Rng::new(5);
        // deliberately NOT integral: the fallback f64 path must kick in
        b.insert("w1q".into(), Tensor::from_mat(&rand_mat(&mut rng, d.f, d.hidden)));
        b.insert("w2q".into(), Tensor::from_mat(&rand_mat(&mut rng, d.hidden, d.classes)));
        let oracle = exec::execute_mat(&g, &b).unwrap();
        let got = run_graph_mat(&g, &b).unwrap();
        assert!(oracle.max_abs_diff(&got) < 1e-4);
    }

    #[test]
    fn rebinding_new_weights_invalidates_int8_cache() {
        let d = dims();
        let g = build::gcn_quant(d, QuantScales::default());
        let plan = Arc::new(ExecPlan::compile(&g).unwrap());
        let mut inst = PlanInstance::new(plan, Arc::new(WorkerPool::serial()));
        let mut b = gcn_bindings(23);
        let ones = Mat::filled(d.f, d.hidden, 1.0);
        let twos = Mat::filled(d.f, d.hidden, 2.0);
        let w2 = Mat::filled(d.hidden, d.classes, 1.0);
        b.insert("w2q".into(), Tensor::from_mat(&w2));
        b.insert("w1q".into(), Tensor::from_mat(&ones));
        inst.run(&b).unwrap();
        let out_ones = inst.output_mat(0).unwrap();
        b.insert("w1q".into(), Tensor::from_mat(&twos));
        inst.run(&b).unwrap();
        let out_twos = inst.output_mat(0).unwrap();
        assert!(out_ones.max_abs_diff(&out_twos) > 1e-6, "stale weight cache");
        let oracle = exec::execute_mat(&g, &b).unwrap();
        assert!(oracle.max_abs_diff(&out_twos) < 1e-4);
    }

    #[test]
    fn chain_with_broadcasts_matches_oracle() {
        // reduce → reciprocal → broadcast → mul: the EffOp softmax tail
        let mut g = OpGraph::new("bc-chain");
        let x = g.input("x", &[6, 5], DType::F32, Stage::Compute);
        let sm = g.op(OpKind::ReduceSumRows, &[x], &[6, 1], Stage::Compute);
        let rc_ = g.op(OpKind::Reciprocal, &[sm], &[6, 1], Stage::Compute);
        let bc = g.op(OpKind::BroadcastCol, &[rc_], &[6, 5], Stage::Compute);
        let out = g.op(OpKind::Mul, &[bc, x], &[6, 5], Stage::Compute);
        g.set_output(out);
        let mut b: Bindings = BTreeMap::new();
        let mut rng = Rng::new(41);
        b.insert(
            "x".into(),
            Tensor::from_mat(&Mat::from_fn(6, 5, |_, _| (rng.f64() + 0.5) as f32)),
        );
        let want = exec::execute_mat(&g, &b).unwrap();
        let got = run_graph_mat(&g, &b).unwrap();
        assert!(want.max_abs_diff(&got) < 1e-5);
    }

    #[test]
    fn tile_runner_buckets_clamp_and_reuse() {
        let mut statics = Bindings::new();
        statics.insert("w".into(), Tensor::from_mat(&Mat::eye(4)));
        statics.insert("b".into(), Tensor::from_mat(&Mat::zeros(1, 4)));
        let mut tr = TileRunner::new(
            Arc::new(WorkerPool::serial()),
            8,
            20,
            20,
            statics,
            |rows, ring| build::gcn_layer_tile(rows, ring, 4, 4, false),
        );
        assert_eq!(tr.bucket(3, 5), (8, 8), "min bucket");
        assert_eq!(tr.bucket(9, 17), (16, 20), "pow2 then capacity clamp");
        assert_eq!(tr.bucket(20, 20), (20, 20), "full tile is exact");
        let _ = tr.tile(3, 5).unwrap();
        let _ = tr.tile(7, 8).unwrap();
        assert_eq!(tr.compiled_tiles(), 1, "same bucket must reuse the tile");
        let _ = tr.tile(20, 20).unwrap();
        assert_eq!(tr.compiled_tiles(), 2);
    }

    #[test]
    fn tile_subset_matches_full_layer_rows() {
        // one GCN layer over a 6-node path graph: recomputing rows {2,3}
        // through a tile must equal those rows of the full-graph layer
        let g = crate::graph::Graph::new(6, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5)]);
        let norm = g.norm_adjacency(6);
        let mut rng = Rng::new(5);
        let x = rand_mat(&mut rng, 6, 3);
        let w = rand_mat(&mut rng, 3, 4);
        let bias = rand_mat(&mut rng, 1, 4);
        // oracle: full layer through the reference executor
        let full = build::gcn_layer_tile(6, 6, 3, 4, true);
        let mut fb: Bindings = BTreeMap::new();
        fb.insert("h_ring".into(), Tensor::from_mat(&x));
        fb.insert("norm_sub".into(), Tensor::from_mat(&norm));
        fb.insert("w".into(), Tensor::from_mat(&w));
        fb.insert("b".into(), Tensor::from_mat(&bias));
        let want = exec::execute_mat(&full, &fb).unwrap();

        let rows = [2usize, 3];
        let ring = [1usize, 2, 3, 4]; // B(rows, 1)
        let mut statics = Bindings::new();
        statics.insert("w".into(), Tensor::from_mat(&w));
        statics.insert("b".into(), Tensor::from_mat(&bias));
        let mut tr = TileRunner::new(
            Arc::new(WorkerPool::serial()),
            2,
            6,
            6,
            statics,
            |r, q| build::gcn_layer_tile(r, q, 3, 4, true),
        );
        let tile = tr.tile(rows.len(), ring.len()).unwrap();
        kernels::gather_rows(&x.data, 3, &ring, tile.binding_mut("h_ring").unwrap());
        kernels::gather_submatrix(
            &norm.data,
            6,
            &rows,
            &ring,
            tile.binding_mut("norm_sub").unwrap(),
            tile.ring,
        );
        tile.run().unwrap();
        let (out, _, cols) = tile.output().unwrap();
        for (slot, &r) in rows.iter().enumerate() {
            for j in 0..4 {
                let d = (out[slot * cols + j] - want[(r, j)]).abs();
                assert!(d < 1e-5, "row {r} col {j} drift {d}");
            }
        }
    }

    #[test]
    fn missing_binding_is_a_clean_error() {
        let g = build::gcn_stagr(dims(), "stagr");
        let err = run_graph(&g, &Bindings::new()).unwrap_err().to_string();
        assert!(err.contains("unbound"), "{err}");
    }
}
