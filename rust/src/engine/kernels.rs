//! Allocation-free op kernels for the planned executor.
//!
//! Every kernel writes into a caller-provided output slice (an arena
//! slab) and mirrors the numerics of [`crate::ops::exec`] — same
//! accumulation order, same guards — so a plan run is bit-comparable to
//! the reference executor. MatMul-shaped kernels row-shard across the
//! [`WorkerPool`]; each lane computes a disjoint block of output rows,
//! which keeps within-row accumulation order identical to serial.

use super::pool::{par_rows, par_rows_nnz, SharedOut, WorkerPool};
use crate::tensor::{
    matmul_block, matmul_block_simd, spmm_rows, spmm_rows_simd, DensityHint,
};

/// Default chunks-per-lane granularity for the nnz-balanced SpMM
/// dispenser (see [`crate::engine::pool::par_rows_nnz`]): enough bins
/// that a straggler chunk overshoots the mean lane by ≲ 1/bins, few
/// enough that CAS dispatch stays noise.
pub const DEGREE_BINS_DEFAULT: usize = 8;

/// `out = a(m×k) @ b(k×n)`, row-sharded; the zero-skip kernel is chosen
/// from the lhs' sampled density (GraSp skip for sparse masks, branch-free
/// for dense activations). SIMD register blocking on; plans with an
/// explicit [`crate::ops::plan::KernelConfig`] go through [`matmul_with`].
pub fn matmul(
    pool: &WorkerPool,
    a: &[f32],
    m: usize,
    k: usize,
    b: &[f32],
    n: usize,
    out: &mut [f32],
) {
    matmul_with(pool, a, m, k, b, n, out, DensityHint::Sample, true);
}

/// [`matmul`] with an explicit density hint (skips the per-call probe
/// when the plan already knows the operand class) and SIMD toggle. Both
/// kernels and both skip modes agree bitwise, so the flags are pure
/// throughput knobs.
#[allow(clippy::too_many_arguments)]
pub fn matmul_with(
    pool: &WorkerPool,
    a: &[f32],
    m: usize,
    k: usize,
    b: &[f32],
    n: usize,
    out: &mut [f32],
    hint: DensityHint,
    simd: bool,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let skip = hint.resolve(a);
    let outp = SharedOut(out.as_mut_ptr());
    par_rows(pool, m, 4, &|r0, r1| {
        // SAFETY: row blocks are disjoint per lane.
        let ob = unsafe {
            std::slice::from_raw_parts_mut(outp.0.add(r0 * n), (r1 - r0) * n)
        };
        if simd {
            matmul_block_simd(&a[r0 * k..r1 * k], r1 - r0, k, b, n, ob, skip);
        } else {
            matmul_block(&a[r0 * k..r1 * k], r1 - r0, k, b, n, ob, skip);
        }
    });
}

/// Sparse × dense matmul over CSR arrays: `out(m×n) = A @ rhs(k×n)` with
/// `A` given as indptr/indices/values. Row-sharded; per-row accumulation
/// runs in ascending column order, matching the dense zero-skip kernel's
/// k-order, so the SpMM path agrees bitwise with [`matmul`] on equal
/// values. O(nnz·n) work — the GraSp model made real.
#[allow(clippy::too_many_arguments)]
pub fn spmm(
    pool: &WorkerPool,
    indptr: &[u32],
    indices: &[u32],
    values: &[f32],
    m: usize,
    rhs: &[f32],
    n: usize,
    out: &mut [f32],
) {
    spmm_with(
        pool,
        indptr,
        indices,
        values,
        m,
        rhs,
        n,
        out,
        DEGREE_BINS_DEFAULT,
        true,
    );
}

/// [`spmm`] with explicit scheduling and SIMD knobs: `bins` is the
/// chunks-per-lane granularity of the nnz-balanced dispenser (row chunks
/// carry equal stored-entry counts, so power-law hub rows stop being
/// stragglers), `simd` selects the neighbor-blocked kernel. All
/// combinations agree bitwise — per-row work and per-element
/// accumulation order never change.
#[allow(clippy::too_many_arguments)]
pub fn spmm_with(
    pool: &WorkerPool,
    indptr: &[u32],
    indices: &[u32],
    values: &[f32],
    m: usize,
    rhs: &[f32],
    n: usize,
    out: &mut [f32],
    bins: usize,
    simd: bool,
) {
    debug_assert_eq!(indptr.len(), m + 1);
    debug_assert_eq!(indices.len(), values.len());
    debug_assert_eq!(out.len(), m * n);
    let outp = SharedOut(out.as_mut_ptr());
    par_rows_nnz(pool, indptr, 8, bins, &|r0, r1| {
        // SAFETY: row blocks are disjoint per lane.
        let ob = unsafe {
            std::slice::from_raw_parts_mut(outp.0.add(r0 * n), (r1 - r0) * n)
        };
        if simd {
            spmm_rows_simd(indptr, indices, values, r0, r1, rhs, n, ob);
        } else {
            spmm_rows(indptr, indices, values, r0, r1, rhs, n, ob);
        }
    });
}

/// INT8 SpMM: quantized CSR values × i8 dense rhs, i32 accumulation, one
/// f32 rescale — the QuantGr datapath applied to the sparse aggregation
/// (the INT8 sibling of [`qmatmul_i8`]). Row-sharded.
#[allow(clippy::too_many_arguments)]
pub fn spmm_i8(
    pool: &WorkerPool,
    indptr: &[u32],
    indices: &[u32],
    values: &[i8],
    m: usize,
    rhs: &[i8],
    n: usize,
    scale: f32,
    out: &mut [f32],
) {
    spmm_i8_with(
        pool,
        indptr,
        indices,
        values,
        m,
        rhs,
        n,
        scale,
        out,
        DEGREE_BINS_DEFAULT,
        true,
    );
}

/// [`spmm_i8`] with scheduling and SIMD knobs. The SIMD variant streams
/// whole rhs rows through 8-lane i32 accumulator blocks (the scalar path
/// reads rhs column-strided, one element per neighbor); i32 addition is
/// associative, so both variants produce identical accumulators and the
/// same single f32 rescale.
#[allow(clippy::too_many_arguments)]
pub fn spmm_i8_with(
    pool: &WorkerPool,
    indptr: &[u32],
    indices: &[u32],
    values: &[i8],
    m: usize,
    rhs: &[i8],
    n: usize,
    scale: f32,
    out: &mut [f32],
    bins: usize,
    simd: bool,
) {
    const JW: usize = 8;
    debug_assert_eq!(indptr.len(), m + 1);
    debug_assert_eq!(indices.len(), values.len());
    debug_assert_eq!(out.len(), m * n);
    let outp = SharedOut(out.as_mut_ptr());
    par_rows_nnz(pool, indptr, 8, bins, &|r0, r1| {
        // SAFETY: row blocks are disjoint per lane.
        let ob = unsafe {
            std::slice::from_raw_parts_mut(outp.0.add(r0 * n), (r1 - r0) * n)
        };
        for i in r0..r1 {
            let (a, b) = (indptr[i] as usize, indptr[i + 1] as usize);
            let orow = &mut ob[(i - r0) * n..(i - r0 + 1) * n];
            if simd {
                let mut j = 0usize;
                while j < n {
                    let w = (n - j).min(JW);
                    let mut acc = [0i32; JW];
                    for p in a..b {
                        let v = values[p] as i32;
                        let base = indices[p] as usize * n + j;
                        let brow = &rhs[base..base + w];
                        for (l, &bv) in brow.iter().enumerate() {
                            acc[l] += v * bv as i32;
                        }
                    }
                    for (l, o) in orow[j..j + w].iter_mut().enumerate() {
                        *o = acc[l] as f32 * scale;
                    }
                    j += w;
                }
            } else {
                for (j, o) in orow.iter_mut().enumerate() {
                    let mut acc: i32 = 0;
                    for p in a..b {
                        acc += values[p] as i32
                            * rhs[indices[p] as usize * n + j] as i32;
                    }
                    *o = acc as f32 * scale;
                }
            }
        }
    });
}

/// A QMatMul operand: planned i8 data or oracle-style rounded f32.
pub enum QOperand<'a> {
    F32(&'a [f32]),
    I8(&'a [i8]),
}

impl QOperand<'_> {
    #[inline]
    fn get(&self, i: usize) -> f64 {
        match self {
            QOperand::F32(d) => d[i] as f64,
            QOperand::I8(d) => d[i] as f64,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            QOperand::F32(d) => d.len(),
            QOperand::I8(d) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The real INT8 path: i8×i8 → i32 accumulate → one f32 rescale, exactly
/// the QuantGr DPU datapath. Row-sharded.
#[allow(clippy::too_many_arguments)]
pub fn qmatmul_i8(
    pool: &WorkerPool,
    x: &[i8],
    w: &[i8],
    m: usize,
    k: usize,
    n: usize,
    scale: f32,
    out: &mut [f32],
) {
    qmatmul_i8_with(pool, x, w, m, k, n, scale, out, true);
}

/// [`qmatmul_i8`] with a SIMD toggle. The SIMD variant register-blocks
/// 4×16 i32 output tiles and streams weight rows (the scalar path reads
/// `w` column-strided); i32 accumulation is associative, so both produce
/// identical accumulators and rescales.
#[allow(clippy::too_many_arguments)]
pub fn qmatmul_i8_with(
    pool: &WorkerPool,
    x: &[i8],
    w: &[i8],
    m: usize,
    k: usize,
    n: usize,
    scale: f32,
    out: &mut [f32],
    simd: bool,
) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let outp = SharedOut(out.as_mut_ptr());
    par_rows(pool, m, 4, &|r0, r1| {
        // SAFETY: row blocks are disjoint per lane.
        let ob = unsafe {
            std::slice::from_raw_parts_mut(outp.0.add(r0 * n), (r1 - r0) * n)
        };
        if simd {
            qmatmul_i8_rows_simd(x, w, r0, r1, k, n, scale, ob);
        } else {
            for i in 0..r1 - r0 {
                let xr = &x[(r0 + i) * k..(r0 + i) * k + k];
                for j in 0..n {
                    let mut acc: i32 = 0;
                    for (kk, &xv) in xr.iter().enumerate() {
                        acc += xv as i32 * w[kk * n + j] as i32;
                    }
                    ob[i * n + j] = acc as f32 * scale;
                }
            }
        }
    });
}

/// Register-blocked i8 GEMM over a row block: 4×16 i32 accumulator tiles,
/// weight rows streamed contiguously. Exact — integer accumulation.
#[allow(clippy::too_many_arguments)]
fn qmatmul_i8_rows_simd(
    x: &[i8],
    w: &[i8],
    r0: usize,
    r1: usize,
    k: usize,
    n: usize,
    scale: f32,
    ob: &mut [f32],
) {
    const IR: usize = 4;
    const JW: usize = 16;
    let rows = r1 - r0;
    let mut i = 0usize;
    while i + IR <= rows {
        let mut j = 0usize;
        while j + JW <= n {
            let mut acc = [[0i32; JW]; IR];
            for kk in 0..k {
                let wp = &w[kk * n + j..kk * n + j + JW];
                for (r, acc_row) in acc.iter_mut().enumerate() {
                    let xv = x[(r0 + i + r) * k + kk] as i32;
                    for (l, &wv) in wp.iter().enumerate() {
                        acc_row[l] += xv * wv as i32;
                    }
                }
            }
            for (r, acc_row) in acc.iter().enumerate() {
                for (l, &av) in acc_row.iter().enumerate() {
                    ob[(i + r) * n + j + l] = av as f32 * scale;
                }
            }
            j += JW;
        }
        while j < n {
            for r in 0..IR {
                let xr = &x[(r0 + i + r) * k..(r0 + i + r) * k + k];
                let mut acc: i32 = 0;
                for (kk, &xv) in xr.iter().enumerate() {
                    acc += xv as i32 * w[kk * n + j] as i32;
                }
                ob[(i + r) * n + j] = acc as f32 * scale;
            }
            j += 1;
        }
        i += IR;
    }
    while i < rows {
        let xr = &x[(r0 + i) * k..(r0 + i) * k + k];
        for j in 0..n {
            let mut acc: i32 = 0;
            for (kk, &xv) in xr.iter().enumerate() {
                acc += xv as i32 * w[kk * n + j] as i32;
            }
            ob[i * n + j] = acc as f32 * scale;
        }
        i += 1;
    }
}

/// Fallback QMatMul for operands that are not provably int8: f64
/// accumulation mirroring the reference executor's INT32-accumulator
/// model bit for bit.
#[allow(clippy::too_many_arguments)]
pub fn qmatmul_acc64(
    pool: &WorkerPool,
    x: &QOperand<'_>,
    w: &QOperand<'_>,
    m: usize,
    k: usize,
    n: usize,
    scale: f32,
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let outp = SharedOut(out.as_mut_ptr());
    par_rows(pool, m, 4, &|r0, r1| {
        // SAFETY: row blocks are disjoint per lane.
        let ob = unsafe {
            std::slice::from_raw_parts_mut(outp.0.add(r0 * n), (r1 - r0) * n)
        };
        for i in 0..r1 - r0 {
            let row = r0 + i;
            for j in 0..n {
                let mut acc = 0.0f64;
                for kk in 0..k {
                    acc += x.get(row * k + kk) * w.get(kk * n + j);
                }
                ob[i * n + j] = (acc as f32) * scale;
            }
        }
    });
}

/// `out(c×r) = a(r×c)ᵀ`.
pub fn transpose(a: &[f32], r: usize, c: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), r * c);
    debug_assert_eq!(out.len(), r * c);
    for i in 0..r {
        for j in 0..c {
            out[j * r + i] = a[i * c + j];
        }
    }
}

/// Elementwise combine with Add-style broadcasting (rhs `(1,n)` or
/// `(m,1)`) — the planned mirror of `exec::broadcast_zip`.
#[allow(clippy::too_many_arguments)]
pub fn zip_broadcast(
    a: &[f32],
    ar: usize,
    ac: usize,
    b: &[f32],
    br: usize,
    bc: usize,
    out: &mut [f32],
    f: impl Fn(f32, f32) -> f32,
) {
    debug_assert_eq!(a.len(), ar * ac);
    debug_assert_eq!(b.len(), br * bc);
    debug_assert_eq!(out.len(), ar * ac);
    for i in 0..ar {
        let bi = if br == 1 { 0 } else { i };
        for j in 0..ac {
            let bj = if bc == 1 { 0 } else { j };
            out[i * ac + j] = f(a[i * ac + j], b[bi * bc + bj]);
        }
    }
}

/// Elementwise map.
pub fn map_unary(a: &[f32], out: &mut [f32], f: impl Fn(f32) -> f32) {
    debug_assert_eq!(a.len(), out.len());
    for (o, &x) in out.iter_mut().zip(a) {
        *o = f(x);
    }
}

/// Row-wise sum: `(m,n) → (m,1)`.
pub fn reduce_sum_rows(a: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), rows);
    for i in 0..rows {
        out[i] = a[i * cols..(i + 1) * cols].iter().sum();
    }
}

/// Row-wise max: `(m,n) → (m,1)`.
pub fn reduce_max_rows(a: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), rows);
    for i in 0..rows {
        out[i] = a[i * cols..(i + 1) * cols]
            .iter()
            .copied()
            .fold(f32::NEG_INFINITY, f32::max);
    }
}

/// Row-wise numerically-stable softmax with the reference executor's
/// fully-masked-row guard.
pub fn softmax(a: &[f32], rows: usize, cols: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), rows * cols);
    debug_assert_eq!(out.len(), rows * cols);
    for i in 0..rows {
        let row = &a[i * cols..(i + 1) * cols];
        let orow = &mut out[i * cols..(i + 1) * cols];
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f32;
        for (o, &x) in orow.iter_mut().zip(row) {
            let e = if (x - m).is_nan() { 0.0 } else { (x - m).exp() };
            *o = e;
            denom += e;
        }
        if denom > 0.0 {
            for o in orow.iter_mut() {
                *o /= denom;
            }
        }
    }
}

/// GrAx3 masked max-pool: `out[i,j] = max_k mask[i,k]·h[k,j]`. Row-sharded.
pub fn masked_max_pool(
    pool: &WorkerPool,
    mask: &[f32],
    m: usize,
    n: usize,
    h: &[f32],
    f: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(mask.len(), m * n);
    debug_assert_eq!(h.len(), n * f);
    debug_assert_eq!(out.len(), m * f);
    let outp = SharedOut(out.as_mut_ptr());
    par_rows(pool, m, 4, &|r0, r1| {
        // SAFETY: row blocks are disjoint per lane.
        let ob = unsafe {
            std::slice::from_raw_parts_mut(outp.0.add(r0 * f), (r1 - r0) * f)
        };
        for i in 0..r1 - r0 {
            let mrow = &mask[(r0 + i) * n..(r0 + i) * n + n];
            for j in 0..f {
                let mut best = f32::NEG_INFINITY;
                for (kk, &mv) in mrow.iter().enumerate() {
                    best = best.max(mv * h[kk * f + j]);
                }
                ob[i * f + j] = best;
            }
        }
    });
}

/// `(cond, a, b) → cond > 0 ? a : b`, all same shape.
pub fn select(cond: &[f32], a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(cond.len(), a.len());
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(out.len(), a.len());
    for idx in 0..out.len() {
        out[idx] = if cond[idx] > 0.0 { a[idx] } else { b[idx] };
    }
}

/// Degrees (self loop included) from an `(m,2)` edge list.
pub fn degrees_from_edges(edges: &[i32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), n);
    out.fill(1.0);
    for e in edges.chunks_exact(2) {
        out[e[0] as usize] += 1.0;
        out[e[1] as usize] += 1.0;
    }
}

/// Dense `A + I` from an edge list.
pub fn adjacency_from_edges(edges: &[i32], n: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), n * n);
    out.fill(0.0);
    for e in edges.chunks_exact(2) {
        let (s, d) = (e[0] as usize, e[1] as usize);
        out[s * n + d] = 1.0;
        out[d * n + s] = 1.0;
    }
    for i in 0..n {
        out[i * n + i] = 1.0;
    }
}

/// Symmetric scatter-add with self contribution.
pub fn scatter_add_edges(edges: &[i32], x: &[f32], n: usize, f: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), n * f);
    debug_assert_eq!(out.len(), n * f);
    out.copy_from_slice(x);
    for e in edges.chunks_exact(2) {
        let (s, d) = (e[0] as usize, e[1] as usize);
        for j in 0..f {
            out[d * f + j] += x[s * f + j];
        }
        for j in 0..f {
            out[s * f + j] += x[d * f + j];
        }
    }
}

/// Sentinel-aware neighbor gather-max (`idx (n,w)`, sentinel ≥ n → skip;
/// all-sentinel rows yield 0, as in the reference executor).
pub fn neighbor_gather_max(
    idx: &[i32],
    w: usize,
    h: &[f32],
    n: usize,
    f: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(h.len(), n * f);
    debug_assert_eq!(out.len(), n * f);
    for i in 0..n {
        for j in 0..f {
            let mut best = f32::NEG_INFINITY;
            for k in 0..w {
                let t = idx[i * w + k] as usize;
                if t < n {
                    best = best.max(h[t * f + j]);
                }
            }
            out[i * f + j] = if best.is_finite() { best } else { 0.0 };
        }
    }
}

// ---------------------------------------------------------------------------
// Gather/scatter — the partial-execution primitives of the incremental
// engine: pull a node subset's rows into a padded tile buffer, push the
// recomputed rows back into the layer-activation cache.
// ---------------------------------------------------------------------------

/// Gather `rows` of a `(_, width)` row-major matrix into the head of
/// `out` (one contiguous row per subset entry). `out` may be longer than
/// `rows.len() * width`; the tail is left untouched (tile padding is
/// zeroed by the tile owner, see [`super::Tile`]).
pub fn gather_rows(src: &[f32], width: usize, rows: &[usize], out: &mut [f32]) {
    debug_assert!(out.len() >= rows.len() * width);
    for (slot, &r) in rows.iter().enumerate() {
        out[slot * width..(slot + 1) * width]
            .copy_from_slice(&src[r * width..(r + 1) * width]);
    }
}

/// Scatter `src` (one contiguous row per subset entry) back into `rows`
/// of a `(_, width)` row-major destination — the write half of the
/// partial-execution path.
pub fn scatter_rows(dst: &mut [f32], width: usize, rows: &[usize], src: &[f32]) {
    debug_assert!(src.len() >= rows.len() * width);
    for (slot, &r) in rows.iter().enumerate() {
        dst[r * width..(r + 1) * width]
            .copy_from_slice(&src[slot * width..(slot + 1) * width]);
    }
}

/// Gather the `rows × cols` submatrix of a `(_, src_cols)` row-major
/// matrix into `out` with stride `out_cols`, zero-filling each written
/// row's tail up to `out_cols` (tile padding must multiply as exact 0).
/// Contiguous column subsets (the full-recompute case, where `cols` is
/// `0..n`) take a memcpy fast path.
pub fn gather_submatrix(
    src: &[f32],
    src_cols: usize,
    rows: &[usize],
    cols: &[usize],
    out: &mut [f32],
    out_cols: usize,
) {
    debug_assert!(out.len() >= rows.len() * out_cols);
    debug_assert!(cols.len() <= out_cols);
    let contiguous = !cols.is_empty() && cols[cols.len() - 1] - cols[0] + 1 == cols.len();
    for (slot, &r) in rows.iter().enumerate() {
        let orow = &mut out[slot * out_cols..(slot + 1) * out_cols];
        if contiguous {
            let c0 = cols[0];
            orow[..cols.len()].copy_from_slice(&src[r * src_cols + c0..r * src_cols + c0 + cols.len()]);
        } else {
            for (j, &c) in cols.iter().enumerate() {
                orow[j] = src[r * src_cols + c];
            }
        }
        orow[cols.len()..].fill(0.0);
    }
}

/// CSR variant of [`gather_submatrix`]: gather the `rows × cols` slice
/// of a CSR matrix into a dense tile with stride `out_cols`, zero-filling
/// everything not stored. Frontier rows index straight into `indptr` —
/// O(Σ nnz(row) · log|cols|) instead of O(|rows|·|cols|) dense reads, so
/// a tile gather never touches the n² dense mask at all. `cols` must be
/// sorted ascending. Returns the number of stored entries written (the
/// bytes-shipped accounting the metrics layer reports).
pub fn gather_csr_submatrix(
    indptr: &[u32],
    indices: &[u32],
    values: &[f32],
    rows: &[usize],
    cols: &[usize],
    out: &mut [f32],
    out_cols: usize,
) -> usize {
    debug_assert!(out.len() >= rows.len() * out_cols);
    debug_assert!(cols.len() <= out_cols);
    debug_assert!(cols.windows(2).all(|w| w[0] < w[1]), "cols must be sorted");
    let mut written = 0usize;
    for (slot, &r) in rows.iter().enumerate() {
        let orow = &mut out[slot * out_cols..(slot + 1) * out_cols];
        orow.fill(0.0);
        let (a, b) = (indptr[r] as usize, indptr[r + 1] as usize);
        for p in a..b {
            let c = indices[p] as usize;
            if let Ok(j) = cols.binary_search(&c) {
                orow[j] = values[p];
                written += 1;
            }
        }
    }
    written
}

/// Sentinel-aware neighbor gather-mean.
pub fn neighbor_gather_mean(
    idx: &[i32],
    w: usize,
    h: &[f32],
    n: usize,
    f: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(h.len(), n * f);
    debug_assert_eq!(out.len(), n * f);
    for i in 0..n {
        for j in 0..f {
            let mut sum = 0.0f32;
            let mut cnt = 0.0f32;
            for k in 0..w {
                let t = idx[i * w + k] as usize;
                if t < n {
                    sum += h[t * f + j];
                    cnt += 1.0;
                }
            }
            out[i * f + j] = sum / cnt.max(1.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Mat;

    #[test]
    fn parallel_matmul_matches_serial() {
        let pool = WorkerPool::new(4);
        let a = Mat::from_fn(37, 23, |i, j| ((i * 7 + j * 3) % 9) as f32 - 4.0);
        let b = Mat::from_fn(23, 11, |i, j| ((i * 5 + j) % 7) as f32 - 3.0);
        let want = a.matmul(&b);
        let mut out = vec![0.0f32; 37 * 11];
        matmul(&pool, &a.data, 37, 23, &b.data, 11, &mut out);
        assert_eq!(out, want.data);
    }

    #[test]
    fn parallel_spmm_matches_dense_matmul_bitwise() {
        use crate::tensor::CsrMat;
        let pool = WorkerPool::new(4);
        // norm-like sparse lhs
        let g = crate::graph::Graph::new(
            37,
            &(0..50u32).map(|i| (i % 37, (i * 11 + 1) % 37)).collect::<Vec<_>>(),
        );
        let dense = g.norm_adjacency(37);
        let csr = g.norm_csr(37);
        assert_eq!(CsrMat::from_dense(&dense), csr);
        let h = Mat::from_fn(37, 9, |i, j| ((i * 5 + j) % 7) as f32 - 3.0);
        let mut want = vec![0.0f32; 37 * 9];
        matmul(&pool, &dense.data, 37, 37, &h.data, 9, &mut want);
        let mut got = vec![0.0f32; 37 * 9];
        spmm(&pool, &csr.indptr, &csr.indices, &csr.values, 37, &h.data, 9, &mut got);
        assert_eq!(got, want, "spmm must match the dense zero-skip kernel");
        // serial pool agrees with the parallel one
        let mut serial = vec![0.0f32; 37 * 9];
        let sp = WorkerPool::serial();
        spmm(&sp, &csr.indptr, &csr.indices, &csr.values, 37, &h.data, 9, &mut serial);
        assert_eq!(serial, got);
    }

    #[test]
    fn spmm_i8_matches_qmatmul_oracle_on_int_values() {
        use crate::tensor::CsrMat;
        // quantized sparse mask × quantized activations: the i32-accum
        // SpMM must equal the dense QMatMul oracle on the densified mask
        let pool = WorkerPool::serial();
        let (m, k, n) = (11, 13, 4);
        let dense = Mat::from_fn(m, k, |i, j| {
            if (i * 7 + j * 3) % 5 == 0 {
                ((i * j) % 253) as f32 - 126.0
            } else {
                0.0
            }
        });
        let csr = CsrMat::from_dense(&dense);
        let v8: Vec<i8> = csr.values.iter().map(|&v| v as i8).collect();
        let rhs8: Vec<i8> = (0..k * n).map(|i| ((i * 37) % 255) as i8).collect();
        let rhs_f: Vec<f32> = rhs8.iter().map(|&v| v as f32).collect();
        let mut fast = vec![0.0f32; m * n];
        spmm_i8(&pool, &csr.indptr, &csr.indices, &v8, m, &rhs8, n, 0.125, &mut fast);
        let mut want = vec![0.0f32; m * n];
        qmatmul_acc64(
            &pool,
            &QOperand::F32(&dense.data),
            &QOperand::F32(&rhs_f),
            m,
            k,
            n,
            0.125,
            &mut want,
        );
        assert_eq!(fast, want);
    }

    #[test]
    fn gather_csr_submatrix_matches_dense_gather() {
        use crate::tensor::CsrMat;
        let g = crate::graph::Graph::new(12, &[(0, 3), (1, 2), (2, 5), (4, 7), (7, 11), (3, 9)]);
        let dense = g.norm_adjacency(12);
        let csr = g.norm_csr(12);
        let rows = [1usize, 3, 7];
        let cols = [0usize, 2, 3, 9, 11];
        let out_cols = 7; // padded
        let mut want = vec![9.0f32; rows.len() * out_cols];
        gather_submatrix(&dense.data, 12, &rows, &cols, &mut want, out_cols);
        let mut got = vec![-1.0f32; rows.len() * out_cols];
        let written = gather_csr_submatrix(
            &csr.indptr, &csr.indices, &csr.values, &rows, &cols, &mut got, out_cols,
        );
        assert_eq!(got, want);
        assert_eq!(
            written,
            want.iter().filter(|&&v| v != 0.0).count(),
            "written-entry accounting"
        );
    }

    #[test]
    fn qmatmul_i8_matches_acc64_on_int_values() {
        let pool = WorkerPool::serial();
        let (m, k, n) = (5, 33, 4);
        let x8: Vec<i8> = (0..m * k).map(|i| ((i * 37) % 255) as i8).collect();
        let w8: Vec<i8> = (0..k * n).map(|i| ((i * 91) % 255) as i8).collect();
        let xf: Vec<f32> = x8.iter().map(|&v| v as f32).collect();
        let wf: Vec<f32> = w8.iter().map(|&v| v as f32).collect();
        let mut fast = vec![0.0f32; m * n];
        let mut slow = vec![0.0f32; m * n];
        qmatmul_i8(&pool, &x8, &w8, m, k, n, 0.25, &mut fast);
        qmatmul_acc64(
            &pool,
            &QOperand::F32(&xf),
            &QOperand::F32(&wf),
            m,
            k,
            n,
            0.25,
            &mut slow,
        );
        assert_eq!(fast, slow);
    }

    #[test]
    fn kernel_with_variants_agree_across_simd_and_bins() {
        use crate::tensor::CsrMat;
        let pool = WorkerPool::new(4);
        let (m, k, n) = (29, 41, 19);
        let a = Mat::from_fn(m, k, |i, j| ((i * 7 + j * 3) % 9) as f32 - 4.0);
        let b = Mat::from_fn(k, n, |i, j| ((i * 5 + j) % 7) as f32 - 3.0);
        let mut scalar = vec![0.0f32; m * n];
        matmul_with(
            &pool, &a.data, m, k, &b.data, n, &mut scalar,
            DensityHint::Sample, false,
        );
        for hint in [DensityHint::Sample, DensityHint::Skip, DensityHint::NoSkip] {
            let mut simd = vec![0.0f32; m * n];
            matmul_with(&pool, &a.data, m, k, &b.data, n, &mut simd, hint, true);
            assert_eq!(scalar, simd, "hint {hint:?}");
        }
        // spmm: skewed mask, every (bins, simd) combination bitwise-equal
        let mask = Mat::from_fn(m, m, |i, j| {
            if i == 0 || (i + j) % 11 == 0 {
                ((i * j) % 5) as f32 - 2.0
            } else {
                0.0
            }
        });
        let csr = CsrMat::from_dense(&mask);
        let mut want = vec![0.0f32; m * n];
        spmm_with(
            &pool, &csr.indptr, &csr.indices, &csr.values, m, &b.data, n,
            &mut want, 1, false,
        );
        for bins in [1usize, 4, 16] {
            for simd in [false, true] {
                let mut got = vec![0.0f32; m * n];
                spmm_with(
                    &pool, &csr.indptr, &csr.indices, &csr.values, m, &b.data,
                    n, &mut got, bins, simd,
                );
                assert_eq!(got, want, "bins {bins} simd {simd}");
            }
        }
    }

    #[test]
    fn int8_with_variants_agree_across_simd() {
        use crate::tensor::CsrMat;
        let pool = WorkerPool::new(3);
        let (m, k, n) = (17, 23, 21);
        let x8: Vec<i8> = (0..m * k).map(|i| ((i * 37) % 255) as i8).collect();
        let w8: Vec<i8> = (0..k * n).map(|i| ((i * 91) % 255) as i8).collect();
        let mut scalar = vec![0.0f32; m * n];
        let mut simd = vec![0.0f32; m * n];
        qmatmul_i8_with(&pool, &x8, &w8, m, k, n, 0.5, &mut scalar, false);
        qmatmul_i8_with(&pool, &x8, &w8, m, k, n, 0.5, &mut simd, true);
        assert_eq!(scalar, simd, "qmatmul i8 simd divergence");
        let mask = Mat::from_fn(m, k, |i, j| {
            if (i * 3 + j) % 4 == 0 {
                ((i + j) % 253) as f32 - 126.0
            } else {
                0.0
            }
        });
        let csr = CsrMat::from_dense(&mask);
        let v8: Vec<i8> = csr.values.iter().map(|&v| v as i8).collect();
        let rhs8: Vec<i8> = (0..k * n).map(|i| ((i * 53) % 255) as i8).collect();
        let mut s_scalar = vec![0.0f32; m * n];
        let mut s_simd = vec![0.0f32; m * n];
        spmm_i8_with(
            &pool, &csr.indptr, &csr.indices, &v8, m, &rhs8, n, 0.125,
            &mut s_scalar, 1, false,
        );
        spmm_i8_with(
            &pool, &csr.indptr, &csr.indices, &v8, m, &rhs8, n, 0.125,
            &mut s_simd, 16, true,
        );
        assert_eq!(s_scalar, s_simd, "spmm i8 simd divergence");
    }

    #[test]
    fn softmax_rows_sum_to_one_and_masked_rows_guarded() {
        let a = vec![1.0, 2.0, 3.0, f32::NEG_INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY];
        let mut out = vec![0.0f32; 6];
        softmax(&a, 2, 3, &mut out);
        let s0: f32 = out[..3].iter().sum();
        assert!((s0 - 1.0).abs() < 1e-6);
        assert_eq!(&out[3..], &[0.0, 0.0, 0.0], "fully-masked row stays zero");
    }

    #[test]
    fn zip_broadcast_row_and_col() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let row = vec![10.0, 20.0];
        let col = vec![100.0, 200.0];
        let mut out = vec![0.0f32; 4];
        zip_broadcast(&a, 2, 2, &row, 1, 2, &mut out, |x, y| x + y);
        assert_eq!(out, vec![11.0, 22.0, 13.0, 24.0]);
        zip_broadcast(&a, 2, 2, &col, 2, 1, &mut out, |x, y| x + y);
        assert_eq!(out, vec![101.0, 102.0, 203.0, 204.0]);
    }

    #[test]
    fn gather_scatter_rows_round_trip() {
        let src: Vec<f32> = (0..20).map(|v| v as f32).collect(); // 5×4
        let mut tile = vec![-1.0f32; 3 * 4];
        gather_rows(&src, 4, &[4, 0, 2], &mut tile);
        assert_eq!(&tile[..4], &[16.0, 17.0, 18.0, 19.0]);
        assert_eq!(&tile[4..8], &[0.0, 1.0, 2.0, 3.0]);
        let mut dst = vec![0.0f32; 20];
        scatter_rows(&mut dst, 4, &[4, 0, 2], &tile);
        assert_eq!(&dst[16..20], &[16.0, 17.0, 18.0, 19.0]);
        assert_eq!(&dst[0..4], &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(&dst[8..12], &[8.0, 9.0, 10.0, 11.0]);
        assert_eq!(&dst[4..8], &[0.0; 4], "unlisted rows untouched");
    }

    #[test]
    fn gather_submatrix_pads_and_takes_contiguous_fast_path() {
        let src: Vec<f32> = (0..16).map(|v| v as f32).collect(); // 4×4
        // scattered columns
        let mut out = vec![9.0f32; 2 * 3];
        gather_submatrix(&src, 4, &[1, 3], &[0, 2], &mut out, 3);
        assert_eq!(out, vec![4.0, 6.0, 0.0, 12.0, 14.0, 0.0]);
        // contiguous columns (the full-gather fast path), padded stride
        let mut out = vec![9.0f32; 2 * 4];
        gather_submatrix(&src, 4, &[0, 2], &[1, 2, 3], &mut out, 4);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 0.0, 9.0, 10.0, 11.0, 0.0]);
    }

    #[test]
    fn gather_kernels_sentinel_aware() {
        let idx: Vec<i32> = vec![0, 1, 1, 3, 3, 3];
        let h = vec![1.0, -5.0, 2.0];
        let mut mx = vec![0.0f32; 3];
        neighbor_gather_max(&idx, 2, &h, 3, 1, &mut mx);
        assert_eq!(mx, vec![1.0, -5.0, 0.0]);
        let mut mn = vec![0.0f32; 3];
        neighbor_gather_mean(&idx, 2, &h, 3, 1, &mut mn);
        assert_eq!(mn, vec![-2.0, -5.0, 0.0]);
    }
}
