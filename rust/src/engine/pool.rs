//! An in-tree worker pool for row-sharded kernels (rayon is unavailable
//! offline, and per-call `thread::spawn` would allocate on the hot path).
//!
//! Design constraints, in order: (1) **zero allocations per dispatch** —
//! workers park on a condvar and receive the job as a raw fat pointer, so
//! the steady-state serving loop stays allocation-free; (2) callers block
//! until every worker has finished, which is what makes the borrowed-job
//! pointer sound; (3) a 1-thread pool degenerates to an inline call, so
//! tests (and the allocation-counting hook) can run fully serial.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Borrowed job handed to workers. Raw pointer because the job only lives
/// for the duration of one `run` call; `run` does not return until every
/// worker is done with it, which is the entire safety argument.
struct JobPtr(*const (dyn Fn(usize) + Sync));
// SAFETY: the pointee is `Sync` (shared calls are fine) and `run` blocks
// until `remaining == 0`, so the pointer never outlives its referent.
unsafe impl Send for JobPtr {}

struct PoolState {
    job: Option<JobPtr>,
    epoch: u64,
    remaining: usize,
    /// Lanes whose job invocation panicked this epoch (the worker thread
    /// survives; the panic is re-raised on the dispatching thread).
    panicked: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    work_cv: Condvar,
    done_cv: Condvar,
}

/// Fixed pool of worker threads executing one borrowed job at a time.
pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    /// Serializes dispatch: `WorkerPool` is `Sync` and shared via `Arc`,
    /// so two threads may call [`WorkerPool::run`] concurrently; without
    /// this lock the second would overwrite the in-flight job state.
    dispatch: Mutex<()>,
}

impl WorkerPool {
    /// Pool using `threads` total lanes (the calling thread is lane 0, so
    /// `threads - 1` OS threads are spawned; `threads <= 1` runs inline).
    pub fn new(threads: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                job: None,
                epoch: 0,
                remaining: 0,
                panicked: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let mut workers = Vec::new();
        for lane in 1..threads.max(1) {
            let shared = Arc::clone(&shared);
            workers.push(std::thread::spawn(move || worker_loop(&shared, lane)));
        }
        WorkerPool { shared, workers, dispatch: Mutex::new(()) }
    }

    /// Single-lane pool: every `run` call executes inline on the caller.
    pub fn serial() -> WorkerPool {
        WorkerPool::new(1)
    }

    /// Pool sized to the machine (capped — kernel row counts rarely feed
    /// more than 8 lanes before the memory bus saturates).
    pub fn default_parallel() -> WorkerPool {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        WorkerPool::new(n.min(8))
    }

    /// Total lanes including the caller.
    pub fn threads(&self) -> usize {
        self.workers.len() + 1
    }

    /// Run `job(lane)` once on every lane (caller is lane 0) and wait for
    /// all lanes to finish. Allocation-free. Concurrent callers are
    /// serialized; a panicking job (any lane) is re-raised here only
    /// after every lane has finished with the borrowed pointer.
    pub fn run(&self, job: &(dyn Fn(usize) + Sync)) {
        if self.workers.is_empty() {
            job(0);
            return;
        }
        // ignore poisoning: state is always drained before unwinding
        let _dispatch = self
            .dispatch
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert!(st.job.is_none(), "pool::run is not reentrant");
            st.job = Some(JobPtr(job as *const _));
            st.epoch = st.epoch.wrapping_add(1);
            st.remaining = self.workers.len();
        }
        self.shared.work_cv.notify_all();
        // the caller lane must not unwind past the join below — workers
        // still hold the borrowed job pointer until remaining == 0
        let caller = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(0)));
        let mut st = self.shared.state.lock().unwrap();
        while st.remaining > 0 {
            st = self.shared.done_cv.wait(st).unwrap();
        }
        st.job = None;
        let worker_panics = st.panicked;
        st.panicked = 0;
        drop(st);
        if let Err(payload) = caller {
            std::panic::resume_unwind(payload);
        }
        if worker_panics > 0 {
            panic!("{worker_panics} worker lane(s) panicked during a pool job");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared, lane: usize) {
    let mut seen_epoch = 0u64;
    loop {
        let ptr = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.job.is_some() && st.epoch != seen_epoch {
                    break;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
            seen_epoch = st.epoch;
            st.job.as_ref().unwrap().0
        };
        // SAFETY: `run` holds the job alive until `remaining == 0`.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || unsafe { (&*ptr)(lane) },
        ));
        let mut st = shared.state.lock().unwrap();
        if result.is_err() {
            st.panicked += 1;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done_cv.notify_all();
        }
    }
}

/// Split `rows` into chunks and run `f(r0, r1)` across the pool's lanes,
/// load-balanced through an atomic dispenser. Small row counts (or a
/// serial pool) run inline. Allocation-free.
pub fn par_rows(
    pool: &WorkerPool,
    rows: usize,
    min_chunk: usize,
    f: &(dyn Fn(usize, usize) + Sync),
) {
    if rows == 0 {
        return;
    }
    let lanes = pool.threads();
    if lanes <= 1 || rows < 2 * min_chunk.max(1) {
        f(0, rows);
        return;
    }
    let chunk = (rows / (lanes * 4)).max(min_chunk).max(1);
    let next = AtomicUsize::new(0);
    pool.run(&|_lane| loop {
        let r0 = next.fetch_add(chunk, Ordering::Relaxed);
        if r0 >= rows {
            break;
        }
        f(r0, (r0 + chunk).min(rows));
    });
}

/// Degree-binned variant of [`par_rows`] for CSR row sharding: chunk
/// boundaries are chosen by walking `indptr`, so each claimed chunk
/// carries roughly `nnz / (lanes × bins)` stored entries instead of a
/// fixed row count. On power-law graphs this is the difference between
/// one lane draining a hub row while the rest idle, and every lane
/// retiring equal aggregation work (the EnGN edge-vs-node dispatch
/// insight). Allocation-free: chunks are claimed through a CAS cursor
/// rather than precomputed bin arrays. `bins` is chunks-per-lane — more
/// bins means finer rebalancing at slightly higher dispatch cost.
pub fn par_rows_nnz(
    pool: &WorkerPool,
    indptr: &[u32],
    min_chunk: usize,
    bins: usize,
    f: &(dyn Fn(usize, usize) + Sync),
) {
    let rows = indptr.len().saturating_sub(1);
    if rows == 0 {
        return;
    }
    let lanes = pool.threads();
    if lanes <= 1 || rows < 2 * min_chunk.max(1) {
        f(0, rows);
        return;
    }
    let total = (indptr[rows] - indptr[0]) as usize;
    let target = (total / (lanes * bins.max(1))).max(1);
    let next = AtomicUsize::new(0);
    pool.run(&|_lane| {
        let mut r0 = next.load(Ordering::Relaxed);
        while r0 < rows {
            let r1 = nnz_chunk_end(indptr, r0, rows, target, min_chunk);
            match next.compare_exchange_weak(r0, r1, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => {
                    f(r0, r1);
                    r0 = next.load(Ordering::Relaxed);
                }
                Err(cur) => r0 = cur,
            }
        }
    });
}

/// Advance from `r0` until the chunk holds ≥ `target` stored entries
/// (and ≥ `min_rows` rows, so degree-0 stretches don't degenerate to
/// row-at-a-time dispatch).
fn nnz_chunk_end(
    indptr: &[u32],
    r0: usize,
    rows: usize,
    target: usize,
    min_rows: usize,
) -> usize {
    let mut r1 = r0;
    let mut acc = 0usize;
    while r1 < rows && (acc < target || r1 - r0 < min_rows.max(1)) {
        acc += (indptr[r1 + 1] - indptr[r1]) as usize;
        r1 += 1;
    }
    r1
}

/// [`par_rows`] / [`par_rows_nnz`] with per-lane busy-time accounting —
/// the scheduling-skew probe behind the `skew_balance` bench gate.
/// `lane_busy_ns[lane]` accumulates nanoseconds spent inside `f`;
/// `indptr = None` uses the uniform row-count dispenser, `Some` the
/// nnz-balanced one. The timed wrapper costs two clock reads per chunk,
/// so this stays in benches and tests; production kernels call the
/// untimed dispatchers.
#[allow(clippy::too_many_arguments)]
pub fn par_rows_timed(
    pool: &WorkerPool,
    rows: usize,
    min_chunk: usize,
    indptr: Option<&[u32]>,
    bins: usize,
    f: &(dyn Fn(usize, usize) + Sync),
    lane_busy_ns: &[std::sync::atomic::AtomicU64],
) {
    assert!(lane_busy_ns.len() >= pool.threads(), "one timer slot per lane");
    if let Some(ip) = indptr {
        debug_assert_eq!(ip.len(), rows + 1, "indptr covers every row");
    }
    let timed = |lane: usize, r0: usize, r1: usize| {
        let t0 = std::time::Instant::now();
        f(r0, r1);
        lane_busy_ns[lane]
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    };
    if rows == 0 {
        return;
    }
    let lanes = pool.threads();
    if lanes <= 1 || rows < 2 * min_chunk.max(1) {
        timed(0, 0, rows);
        return;
    }
    let next = AtomicUsize::new(0);
    match indptr {
        None => {
            let chunk = (rows / (lanes * 4)).max(min_chunk).max(1);
            pool.run(&|lane| loop {
                let r0 = next.fetch_add(chunk, Ordering::Relaxed);
                if r0 >= rows {
                    break;
                }
                timed(lane, r0, (r0 + chunk).min(rows));
            });
        }
        Some(ip) => {
            let total = (ip[rows] - ip[0]) as usize;
            let target = (total / (lanes * bins.max(1))).max(1);
            pool.run(&|lane| {
                let mut r0 = next.load(Ordering::Relaxed);
                while r0 < rows {
                    let r1 = nnz_chunk_end(ip, r0, rows, target, min_chunk);
                    match next.compare_exchange_weak(
                        r0,
                        r1,
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    ) {
                        Ok(_) => {
                            timed(lane, r0, r1);
                            r0 = next.load(Ordering::Relaxed);
                        }
                        Err(cur) => r0 = cur,
                    }
                }
            });
        }
    }
}

/// Wrapper making a raw output pointer `Send + Sync` so parallel kernels
/// can carve **disjoint** row blocks out of one output buffer.
#[derive(Clone, Copy)]
pub(crate) struct SharedOut(pub *mut f32);
// SAFETY: users only write disjoint index ranges (per-row sharding).
unsafe impl Send for SharedOut {}
unsafe impl Sync for SharedOut {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn serial_pool_runs_inline() {
        let pool = WorkerPool::serial();
        assert_eq!(pool.threads(), 1);
        let hits = AtomicU64::new(0);
        pool.run(&|lane| {
            assert_eq!(lane, 0);
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn every_lane_participates() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.threads(), 4);
        let mask = AtomicU64::new(0);
        pool.run(&|lane| {
            mask.fetch_or(1 << lane, Ordering::Relaxed);
        });
        assert_eq!(mask.load(Ordering::Relaxed), 0b1111);
    }

    #[test]
    fn repeated_dispatch_is_stable() {
        let pool = WorkerPool::new(3);
        let total = AtomicU64::new(0);
        for _ in 0..200 {
            pool.run(&|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 600);
    }

    #[test]
    fn par_rows_covers_every_row_once() {
        let pool = WorkerPool::new(4);
        let rows = 103;
        let counts: Vec<AtomicU64> = (0..rows).map(|_| AtomicU64::new(0)).collect();
        par_rows(&pool, rows, 1, &|r0, r1| {
            for r in r0..r1 {
                counts[r].fetch_add(1, Ordering::Relaxed);
            }
        });
        for (r, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "row {r}");
        }
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(3);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(&|lane| {
                if lane == 2 {
                    panic!("boom");
                }
            });
        }));
        assert!(res.is_err(), "worker panic must re-raise on the caller");
        // the pool stays functional: state was drained before re-raising
        let total = AtomicU64::new(0);
        pool.run(&|_| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn caller_lane_panic_joins_workers_first() {
        // lane 0 panics: run must still join every worker (they borrow
        // the job pointer) before re-raising, and stay usable after
        let pool = WorkerPool::new(3);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(&|lane| {
                if lane == 0 {
                    panic!("caller boom");
                }
            });
        }));
        assert!(res.is_err());
        let total = AtomicU64::new(0);
        pool.run(&|_| {
            total.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn concurrent_dispatch_is_serialized() {
        // two threads sharing one pool must both complete correctly
        let pool = Arc::new(WorkerPool::new(2));
        let total = Arc::new(AtomicU64::new(0));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let pool = Arc::clone(&pool);
                let total = Arc::clone(&total);
                std::thread::spawn(move || {
                    for _ in 0..50 {
                        pool.run(&|_| {
                            total.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(total.load(Ordering::Relaxed), 4 * 50 * 2);
    }

    #[test]
    fn par_rows_small_input_inline() {
        let pool = WorkerPool::new(4);
        let hits = AtomicU64::new(0);
        par_rows(&pool, 3, 16, &|r0, r1| {
            assert_eq!((r0, r1), (0, 3));
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    /// indptr for a synthetic degree sequence.
    fn indptr_of(degrees: &[u32]) -> Vec<u32> {
        let mut ip = vec![0u32];
        for &d in degrees {
            ip.push(ip.last().unwrap() + d);
        }
        ip
    }

    #[test]
    fn par_rows_nnz_covers_every_row_once() {
        let pool = WorkerPool::new(4);
        // power-law-ish: one hub holding most entries, a zero-degree
        // stretch, then a light tail
        let mut degrees = vec![500u32, 0, 0, 0, 0];
        degrees.extend(vec![2u32; 98]);
        let ip = indptr_of(&degrees);
        let counts: Vec<AtomicU64> =
            (0..degrees.len()).map(|_| AtomicU64::new(0)).collect();
        par_rows_nnz(&pool, &ip, 1, 8, &|r0, r1| {
            assert!(r0 < r1, "chunks are non-empty");
            for r in r0..r1 {
                counts[r].fetch_add(1, Ordering::Relaxed);
            }
        });
        for (r, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "row {r}");
        }
    }

    #[test]
    fn par_rows_nnz_zero_nnz_graph_still_covers() {
        // all-empty rows: the min_chunk floor keeps chunks from
        // degenerating, and every row is still dispatched exactly once
        let pool = WorkerPool::new(3);
        let ip = indptr_of(&[0u32; 40]);
        let counts: Vec<AtomicU64> = (0..40).map(|_| AtomicU64::new(0)).collect();
        par_rows_nnz(&pool, &ip, 4, 8, &|r0, r1| {
            for r in r0..r1 {
                counts[r].fetch_add(1, Ordering::Relaxed);
            }
        });
        for (r, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "row {r}");
        }
    }

    #[test]
    fn par_rows_nnz_small_input_inline() {
        let pool = WorkerPool::new(4);
        let ip = indptr_of(&[3, 1, 2]);
        let hits = AtomicU64::new(0);
        par_rows_nnz(&pool, &ip, 16, 8, &|r0, r1| {
            assert_eq!((r0, r1), (0, 3));
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn par_rows_nnz_chunks_track_entry_counts() {
        // hub rows must land in narrow chunks: no chunk may combine the
        // hub with the whole tail (that is exactly the straggler the
        // nnz dispenser exists to break up)
        let pool = WorkerPool::new(4);
        let mut degrees = vec![1000u32];
        degrees.extend(vec![1u32; 200]);
        let ip = indptr_of(&degrees);
        let max_span = AtomicU64::new(0);
        par_rows_nnz(&pool, &ip, 1, 8, &|r0, r1| {
            if r0 == 0 {
                max_span.fetch_max((r1 - r0) as u64, Ordering::Relaxed);
            }
        });
        assert!(
            max_span.load(Ordering::Relaxed) <= 2,
            "hub chunk spanned {} rows",
            max_span.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn par_rows_timed_accounts_all_lanes() {
        let pool = WorkerPool::new(4);
        let degrees: Vec<u32> = (0..120).map(|i| (i % 7) as u32).collect();
        let ip = indptr_of(&degrees);
        for indptr in [None, Some(ip.as_slice())] {
            let busy: Vec<AtomicU64> =
                (0..pool.threads()).map(|_| AtomicU64::new(0)).collect();
            let counts: Vec<AtomicU64> =
                (0..120).map(|_| AtomicU64::new(0)).collect();
            par_rows_timed(&pool, 120, 1, indptr, 8, &|r0, r1| {
                for r in r0..r1 {
                    counts[r].fetch_add(1, Ordering::Relaxed);
                    std::hint::black_box(r);
                }
            }, &busy);
            for (r, c) in counts.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), 1, "row {r}");
            }
            let total: u64 = busy.iter().map(|b| b.load(Ordering::Relaxed)).sum();
            assert!(total > 0, "busy time recorded");
        }
    }
}
