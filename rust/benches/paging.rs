//! Out-of-core serving bench: memory vs paged feature backends (ISSUE 10
//! acceptance bench).
//!
//! Two claims, two gates:
//!
//! 1. **Warm-cache throughput** — at Cora scale, a paged backend whose
//!    cache holds the working set serves mutation+query rounds at
//!    ≥ 0.8× the in-memory backend (`cora_warm_paged_vs_memory`).
//! 2. **Peak RSS** — a 1M-node power-law graph served through
//!    `Deployment::launch` with `[storage] backend = "paged"` peaks
//!    under a RAM budget the in-memory backend arithmetically cannot
//!    meet: in-memory needs the paged run's footprint *plus* the dense
//!    feature matrix *plus* its NodePad-padded `x_pad` copy, minus the
//!    page-cache arena. The features only ever exist in the store file
//!    (streamed row-by-row at build time; the dataset is headless).
//!
//! ```sh
//! cargo bench --bench paging                     # Cora + 1M point
//! cargo bench --bench paging -- --quick          # CI smoke (same 1M)
//! cargo bench --bench paging -- --json out.json  # artifact
//! ```

use std::sync::Arc;

use grannite::bench::banner;
use grannite::cli::Args;
use grannite::engine::WorkerPool;
use grannite::graph::datasets::{
    power_law_feature_row, synthesize, synthesize_power_law_headless,
};
use grannite::incremental::{IncrementalConfig, IncrementalEngine};
use grannite::serve::{
    DataSource, Deployment, DeploymentSpec, EngineSpec, Serving, Topology,
};
use grannite::server::{InferenceEngine, Update};
use grannite::storage::{spill_path, PagedFeatures, PagedStore};
use grannite::util::timing::Stats;
use grannite::util::{human_bytes, human_us, Table};

const MB: f64 = 1024.0 * 1024.0;

/// Peak resident set of this process (VmHWM), in MB. Monotone over the
/// process lifetime — the 1M point must run as the last/biggest phase.
fn peak_rss_mb() -> f64 {
    let status = std::fs::read_to_string("/proc/self/status").unwrap_or_default();
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: f64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0.0);
            return kb * 1024.0 / MB;
        }
    }
    0.0
}

/// Deterministic mutation+query rounds against one engine: per round,
/// one `AddEdge` then a timed `infer` (the warm-path shape: small
/// frontier ring gathered through whichever feature tier is configured).
fn replay(
    engine: &mut IncrementalEngine,
    nodes: usize,
    rounds: usize,
    seed: u64,
) -> anyhow::Result<(Stats, u64, u64)> {
    let mut rng = grannite::util::Rng::new(seed);
    let mut samples = Vec::with_capacity(rounds);
    let (mut hits, mut faults) = (0u64, 0u64);
    for _ in 0..rounds {
        let u = rng.usize(nodes);
        let mut v = rng.usize(nodes);
        if v == u {
            v = (v + 1) % nodes;
        }
        let _ = engine.apply(&Update::AddEdge(u, v));
        let t0 = std::time::Instant::now();
        let logits = engine.infer()?;
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
        std::hint::black_box(logits);
        if let Some(rs) = engine.round_stats() {
            hits += rs.page_hits;
            faults += rs.page_faults;
        }
    }
    Ok((Stats::from_samples(&samples), hits, faults))
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let quick = args.has("quick");
    let json_path = args.options.get("json").cloned();
    banner(if quick {
        "paged vs in-memory feature serving (quick)"
    } else {
        "paged vs in-memory feature serving"
    });

    // ------------------------------------------------------------------
    // Part 1: Cora-scale warm-cache throughput, memory vs paged
    // ------------------------------------------------------------------
    let (n, m, f, classes) = if quick {
        (600, 1500, 64, 7)
    } else {
        (2708, 5429, 1433, 7)
    };
    let cap = n + 64;
    let rounds = if quick { 12 } else { 30 };
    let ds = synthesize("paging", n, m, classes, f, 11);
    let pool = Arc::new(WorkerPool::default_parallel());
    let cfg = IncrementalConfig::default();

    let mut mem = IncrementalEngine::full(&ds, cap, Arc::clone(&pool), cfg)?;
    let _ = mem.infer()?; // warm: compile + first full round
    let (mem_stats, _, _) = replay(&mut mem, n, rounds, 31)?;

    // cache sized to the working set: every page resident after round one
    let page_rows = 64;
    let cache_pages = cap.div_ceil(page_rows);
    let mut store =
        PagedStore::create_from_mat(&spill_path("paging-cora"), &ds.features, cap)?;
    store.set_delete_on_drop(true);
    let features =
        Box::new(PagedFeatures::new(Arc::new(store), page_rows, cache_pages));
    let mut paged = IncrementalEngine::shard_with_source(
        &ds, cap, 0..cap, Arc::clone(&pool), cfg, features,
    )?;
    let _ = paged.infer()?; // warm: faults every page exactly once
    let _ = paged.round_stats();
    let (paged_stats, hits, faults) = replay(&mut paged, n, rounds, 31)?;

    // numerics + warmth: identical scripts must agree, and the replay
    // rounds must have served from the cache, not the disk
    let diff = mem.infer()?.max_abs_diff(&paged.infer()?);
    let warm_hit_rate = if hits + faults == 0 {
        1.0
    } else {
        hits as f64 / (hits + faults) as f64
    };
    let warm_ratio = mem_stats.mean / paged_stats.mean;

    let mut t = Table::new(
        format!("warm mutation+query rounds — {n} nodes, {f} features"),
        &["backend", "mean", "p50", "p95"],
    );
    t.row(&["memory".into(), human_us(mem_stats.mean),
            human_us(mem_stats.p50), human_us(mem_stats.p95)]);
    t.row(&["paged".into(), human_us(paged_stats.mean),
            human_us(paged_stats.p50), human_us(paged_stats.p95)]);
    t.print();
    println!(
        "warm paged/memory throughput: {warm_ratio:.3}x   \
         hit rate {warm_hit_rate:.3}   max|Δ| = {diff:.3e}"
    );
    drop(paged);
    drop(mem);

    // ------------------------------------------------------------------
    // Part 2: 1M-node power-law graph through Deployment::launch, paged
    // backend, features never resident (streamed into the store file)
    // ------------------------------------------------------------------
    let nodes = 1_000_000;
    let (pl_f, pl_deg, pl_classes, pl_seed) = (64, 6, 7, 13);
    let queries_1m = if quick { 4 } else { 10 };
    println!("\nbuilding 1M-node power-law graph (avg degree {pl_deg}) …");
    let pl = synthesize_power_law_headless("pl-1m", nodes, pl_deg, pl_classes, pl_f, pl_seed);
    let store_path = spill_path("paging-1m");
    let built = PagedStore::create(&store_path, nodes, pl_f, |row, out| {
        power_law_feature_row(pl_seed, row, out);
    })?;
    let store_bytes = nodes * pl_f * 4;
    println!(
        "streamed {} of features into {} ({} rows, never resident)",
        human_bytes(store_bytes),
        store_path.display(),
        built.rows(),
    );
    drop(built);

    let (page_rows_1m, cache_pages_1m) = (256usize, 1024usize);
    let mut spec = DeploymentSpec {
        engine: EngineSpec::named("incremental"),
        topology: Topology::homogeneous(1),
        capacity: nodes,
        ..DeploymentSpec::default()
    };
    spec.storage.backend = "paged".into();
    spec.storage.page_rows = page_rows_1m;
    spec.storage.cache_pages = cache_pages_1m;
    spec.storage.path = store_path.display().to_string();

    let t0 = std::time::Instant::now();
    let fleet = Deployment::launch(&spec, &DataSource::Dataset(pl.clone()))?;
    let launch_s = t0.elapsed().as_secs_f64();
    let mut samples = Vec::with_capacity(queries_1m);
    let mut rng = grannite::util::Rng::new(5);
    for _ in 0..queries_1m {
        fleet.update(Update::AddEdge(rng.usize(nodes), rng.usize(nodes)))?;
        let node = rng.usize(nodes);
        let tq = std::time::Instant::now();
        let _ = fleet.query_wait(Some(node))?;
        samples.push(tq.elapsed().as_secs_f64() * 1e6);
    }
    let q_stats = Stats::from_samples(&samples);
    let snap = fleet.metrics();
    fleet.shutdown()?;
    let _ = std::fs::remove_file(&store_path);

    let paged_peak_mb = peak_rss_mb();
    // what switching this run to backend = "memory" would ADD, computed
    // from geometry (never run — it is the budget-blowing case):
    // the dense feature matrix the dataset would carry, plus the
    // NodePad-padded x_pad copy MemoryFeatures binds, minus the page
    // cache arena the paged run no longer needs
    let cache_arena_mb = (cache_pages_1m * page_rows_1m * pl_f * 4) as f64 / MB;
    let dense_features_mb = (nodes * pl_f * 4) as f64 / MB;
    let xpad_mb = (nodes * pl_f * 4) as f64 / MB;
    let inmem_min_mb = paged_peak_mb - cache_arena_mb + dense_features_mb + xpad_mb;
    // the budget the paged run fits and the in-memory floor blows: the
    // midpoint of the two footprints
    let budget_mb = (paged_peak_mb + inmem_min_mb) / 2.0;

    println!(
        "1M-node paged deployment: launch+first-round {launch_s:.1}s   \
         query mean {}   feature-cache hit rate {:.3}   disk read {}",
        human_us(q_stats.mean),
        snap.feature_cache_hit_rate(),
        human_bytes(snap.storage_bytes_read as usize),
    );
    println!(
        "peak RSS {paged_peak_mb:.0} MB (paged)   vs ≥ {inmem_min_mb:.0} MB \
         (in-memory floor: +{dense_features_mb:.0} MB features \
         +{xpad_mb:.0} MB x_pad −{cache_arena_mb:.0} MB cache arena)   \
         budget {budget_mb:.0} MB"
    );

    if let Some(path) = json_path {
        let mut out = String::from("{\n");
        out.push_str("  \"bench\": \"paging\",\n");
        out.push_str(&format!("  \"quick\": {quick},\n"));
        out.push_str(&format!("  \"cora_nodes\": {n},\n  \"cora_features\": {f},\n"));
        out.push_str(&format!(
            "  \"cora_warm_paged_vs_memory\": {warm_ratio:.4},\n"
        ));
        out.push_str(&format!("  \"cora_warm_hit_rate\": {warm_hit_rate:.4},\n"));
        out.push_str(&format!("  \"cora_max_abs_diff\": {diff:.6e},\n"));
        out.push_str(&format!("  \"pl_nodes\": {nodes},\n"));
        out.push_str(&format!("  \"pl_query_mean_us\": {:.3},\n", q_stats.mean));
        out.push_str(&format!(
            "  \"pl_feature_cache_hit_rate\": {:.4},\n",
            snap.feature_cache_hit_rate()
        ));
        out.push_str(&format!(
            "  \"pl_storage_read_bytes\": {},\n",
            snap.storage_bytes_read
        ));
        out.push_str(&format!("  \"paged_1m_peak_rss_mb\": {paged_peak_mb:.1},\n"));
        out.push_str(&format!("  \"inmem_1m_min_mb\": {inmem_min_mb:.1},\n"));
        out.push_str(&format!("  \"budget_mb\": {budget_mb:.1}\n"));
        out.push_str("}\n");
        std::fs::write(&path, out)?;
        println!("wrote {path}");
    }
    Ok(())
}
