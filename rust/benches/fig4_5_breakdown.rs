//! Regenerates paper Fig. 4 (preprocess/compute × DPU/DSP breakdown) and
//! Fig. 5 (per-op compute breakdown) — DESIGN.md §6.
use grannite::bench::{banner, figures, run_bench};
use grannite::config::HardwareConfig;

fn main() {
    banner("Fig. 4 / Fig. 5 — latency breakdowns (out-of-the-box mapping)");
    let hw = HardwareConfig::npu_series2();
    figures::fig4(&hw).print();
    figures::fig5(&hw).print();
    // harness overhead telemetry: how fast is one full simulation?
    run_bench("simulate(fig4 GraphConv)", 3, 20, || {
        figures::fig4(&hw)
    });
}
