//! Churn-rate sweep: delta-driven incremental inference vs full-graph
//! planned execution (ISSUE 3 acceptance bench).
//!
//! Each churn level replays one deterministic event script (the
//! [`KnowledgeGraphStream`] `churn` knob: exactly N mutations per query)
//! against both engines and reports mean per-query inference latency.
//! At low churn the incremental engine recomputes `O(frontier)` rows;
//! past the fallback threshold it *is* the full path, so high-churn
//! levels measure the regression guard.
//!
//! ```sh
//! cargo bench --bench incremental_churn                     # Cora scale
//! cargo bench --bench incremental_churn -- --quick          # CI smoke
//! cargo bench --bench incremental_churn -- --json out.json  # artifact
//! ```

use std::sync::Arc;

use grannite::bench::banner;
use grannite::cli::Args;
use grannite::engine::WorkerPool;
use grannite::fleet::PlanEngine;
use grannite::graph::datasets::synthesize;
use grannite::graph::stream::{GraphEvent, KnowledgeGraphStream};
use grannite::incremental::{IncrementalConfig, IncrementalEngine};
use grannite::ops::build::Aggregation;
use grannite::server::{InferenceEngine, Update};
use grannite::util::timing::Stats;
use grannite::util::{human_us, Table};

struct Level {
    churn: f64,
    /// Dense full recompute — the gate's fixed baseline (PR-3 semantics:
    /// "delta-driven recompute beats dense full recompute").
    full: Stats,
    /// Sparse (SpMM) full recompute — the production plan engine, shown
    /// for context; the SpMM-vs-dense win has its own gate in
    /// `spmm_scaling`.
    sparse_full: Stats,
    inc: Stats,
    recompute_ratio: f64,
    cache_hit_rate: f64,
    frontier_mean: f64,
    max_abs_diff: f32,
}

/// Materialize the event script for one churn level: exactly `queries`
/// queries with `churn` mutations per query, deterministically.
fn script(nodes: usize, capacity: usize, churn: f64, queries: usize) -> Vec<GraphEvent> {
    let mut out = Vec::new();
    let mut seen = 0usize;
    for ev in KnowledgeGraphStream::with_churn(nodes, capacity, churn, 7) {
        if matches!(ev, GraphEvent::Query) {
            seen += 1;
        }
        out.push(ev);
        if seen == queries {
            break;
        }
    }
    out
}

fn update_of(ev: &GraphEvent) -> Option<Update> {
    match ev {
        GraphEvent::AddEdge(u, v) => Some(Update::AddEdge(*u, *v)),
        GraphEvent::RemoveEdge(u, v) => Some(Update::RemoveEdge(*u, *v)),
        GraphEvent::AddNode => Some(Update::AddNode),
        GraphEvent::Query => None,
    }
}

/// Replay a script against an engine, timing every query-round infer.
fn replay<E: InferenceEngine>(engine: &mut E, events: &[GraphEvent])
                              -> anyhow::Result<(Stats, Vec<grannite::metrics::RoundStats>)> {
    let mut samples = Vec::new();
    let mut rounds = Vec::new();
    for ev in events {
        match update_of(ev) {
            Some(u) => {
                // capacity exhaustion is a stream artifact, not a failure
                let _ = engine.apply(&u);
            }
            None => {
                let t0 = std::time::Instant::now();
                let logits = engine.infer()?;
                samples.push(t0.elapsed().as_secs_f64() * 1e6);
                std::hint::black_box(logits);
                if let Some(rs) = engine.round_stats() {
                    rounds.push(rs);
                }
            }
        }
    }
    Ok((Stats::from_samples(&samples), rounds))
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let quick = args.has("quick");
    let json_path = args.options.get("json").cloned();
    banner(if quick {
        "incremental churn sweep (quick)"
    } else {
        "incremental churn sweep (Cora scale)"
    });

    // Cora-scale by default (2708 nodes, 1433 features, capacity 3000);
    // --quick shrinks the twin so hosted CI finishes in seconds while
    // keeping the same churn regimes
    let (n, m, f, classes, cap) = if quick {
        (600, 1500, 64, 7, 660)
    } else {
        (2708, 5429, 1433, 7, 3000)
    };
    let ds = synthesize("churn", n, m, classes, f, 11);
    let queries = if quick { 12 } else { 40 };
    let churns: &[f64] = &[0.25, 1.0, 4.0, 16.0, 64.0];
    let pool = Arc::new(WorkerPool::default_parallel());

    let mut levels: Vec<Level> = Vec::new();
    for &churn in churns {
        let events = script(n, cap, churn, queries);

        let mut inc = IncrementalEngine::full(
            &ds, cap, Arc::clone(&pool), IncrementalConfig::default(),
        )?;
        let _ = inc.infer()?; // seed: compile + first full round
        let _ = inc.round_stats();
        let (inc_stats, rounds) = replay(&mut inc, &events)?;

        // the gate's baseline stays pinned to the *dense* full recompute
        // so its 1.5x floor keeps PR-3 semantics; the sparse engine is
        // measured alongside for context
        let mut full =
            PlanEngine::full_with(&ds, cap, Arc::clone(&pool), Aggregation::Dense)?;
        let _ = full.infer()?; // warm: plan compile + arena + bindings
        let (full_stats, _) = replay(&mut full, &events)?;

        let mut sfull =
            PlanEngine::full_with(&ds, cap, Arc::clone(&pool), Aggregation::Sparse)?;
        let _ = sfull.infer()?;
        let (sparse_stats, _) = replay(&mut sfull, &events)?;

        // numerics: all three engines must still agree after the script
        let a = inc.infer()?;
        let b = full.infer()?;
        let c = sfull.infer()?;
        let max_abs_diff = a.max_abs_diff(&b).max(b.max_abs_diff(&c));

        let (mut rec, mut eli, mut hits, mut misses, mut fr) =
            (0usize, 0usize, 0usize, 0usize, 0.0f64);
        for r in &rounds {
            rec += r.recomputed_rows;
            eli += r.eligible_rows;
            hits += r.cache_hits;
            misses += r.cache_misses;
            fr += r.frontier as f64;
        }
        levels.push(Level {
            churn,
            full: full_stats,
            sparse_full: sparse_stats,
            inc: inc_stats,
            recompute_ratio: if eli == 0 { 0.0 } else { rec as f64 / eli as f64 },
            cache_hit_rate: if hits + misses == 0 {
                0.0
            } else {
                hits as f64 / (hits + misses) as f64
            },
            frontier_mean: if rounds.is_empty() {
                0.0
            } else {
                fr / rounds.len() as f64
            },
            max_abs_diff,
        });
    }

    let mut t = Table::new(
        format!("incremental vs full planned execution — {n} nodes, {f} features"),
        &["mut/query", "dense full", "spmm full", "incr mean", "speedup",
          "recompute", "cache hit", "frontier"],
    );
    for l in &levels {
        t.row(&[
            format!("{:.2}", l.churn),
            human_us(l.full.mean),
            human_us(l.sparse_full.mean),
            human_us(l.inc.mean),
            format!("{:.2}x", l.full.mean / l.inc.mean),
            format!("{:.3}", l.recompute_ratio),
            format!("{:.3}", l.cache_hit_rate),
            format!("{:.1}", l.frontier_mean),
        ]);
    }
    t.print();

    // headline gates: the ≤1 mutation/query win and the beyond-threshold
    // regression guard
    let low = levels
        .iter()
        .find(|l| (l.churn - 1.0).abs() < 1e-9)
        .expect("churn=1 level");
    let high = levels.last().unwrap();
    let low_churn_speedup = low.full.mean / low.inc.mean;
    let high_churn_parity = high.full.mean / high.inc.mean;
    let worst_diff = levels
        .iter()
        .map(|l| l.max_abs_diff)
        .fold(0.0f32, f32::max);
    println!(
        "\nlow-churn (1 mut/query) speedup: {low_churn_speedup:.2}x   \
         high-churn parity: {high_churn_parity:.2}x   max|Δ| = {worst_diff:.3e}"
    );

    if let Some(path) = json_path {
        let mut out = String::from("{\n");
        out.push_str("  \"bench\": \"incremental_churn\",\n");
        out.push_str(&format!("  \"quick\": {quick},\n"));
        out.push_str(&format!("  \"nodes\": {n},\n  \"features\": {f},\n"));
        out.push_str(&format!(
            "  \"low_churn_speedup\": {low_churn_speedup:.4},\n"
        ));
        out.push_str(&format!(
            "  \"high_churn_parity\": {high_churn_parity:.4},\n"
        ));
        out.push_str(&format!("  \"max_abs_diff\": {worst_diff:.6e},\n"));
        out.push_str("  \"levels\": [\n");
        for (i, l) in levels.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"churn\": {:.2}, \"full_mean_us\": {:.3}, \
                 \"sparse_full_mean_us\": {:.3}, \
                 \"inc_mean_us\": {:.3}, \"speedup\": {:.4}, \
                 \"recompute_ratio\": {:.4}, \"cache_hit_rate\": {:.4}, \
                 \"frontier_mean\": {:.2}}}{}\n",
                l.churn,
                l.full.mean,
                l.sparse_full.mean,
                l.inc.mean,
                l.full.mean / l.inc.mean,
                l.recompute_ratio,
                l.cache_hit_rate,
                l.frontier_mean,
                if i + 1 < levels.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        std::fs::write(&path, out)?;
        println!("wrote {path}");
    }
    Ok(())
}
