//! Autotuner gate: `Deployment::autotune` against the default hand
//! mapping (`DeploymentSpec::default()` — FP32 plan engine, 1 shard) on
//! the hotpath serving workload (a GrAd churn burst, then a query
//! storm). The headline number is
//! `autotuned_vs_default_speedup = tuned q/s ÷ default q/s`; CI gates it
//! at ≥ 0.95 — the tuner may tie the default (the default mapping is in
//! its search space) but must never pick something materially worse.
//!
//! ```sh
//! cargo bench --bench autotune                     # full sizes
//! cargo bench --bench autotune -- --quick          # CI smoke sizes
//! cargo bench --bench autotune -- --json out.json  # machine-readable
//! ```

use std::time::Instant;

use grannite::bench::banner;
use grannite::cli::Args;
use grannite::graph::datasets::synthesize;
use grannite::serve::{DataSource, Deployment, DeploymentSpec, Serving};
use grannite::server::Update;
use grannite::util::{json_escape, Rng, Table};

struct Sizes {
    nodes: usize,
    edges: usize,
    queries: usize,
    churn: usize,
    probe_budget: usize,
}

/// Churn burst, then a query storm; returns measured queries/second
/// over the storm (the same shape the tuner's live probes measure).
fn drive(serving: &dyn Serving, sz: &Sizes) -> anyhow::Result<f64> {
    let mut rng = Rng::new(17);
    for _ in 0..sz.churn {
        let u = rng.usize(sz.nodes);
        let v = (u + 1 + rng.usize(sz.nodes - 1)) % sz.nodes;
        serving.update(Update::AddEdge(u.min(v), u.max(v)))?;
    }
    let t0 = Instant::now();
    let pending: Vec<_> = (0..sz.queries)
        .map(|_| serving.query(Some(rng.usize(sz.nodes))))
        .collect::<anyhow::Result<_>>()?;
    for rx in pending {
        rx.recv()?.map_err(anyhow::Error::msg)?;
    }
    Ok(sz.queries as f64 / t0.elapsed().as_secs_f64())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let quick = args.has("quick");
    let json_path = args.options.get("json").cloned();
    banner("autotune vs default mapping (hotpath serving workload)");

    let sz = if quick {
        Sizes { nodes: 256, edges: 1024, queries: 300, churn: 64, probe_budget: 32 }
    } else {
        Sizes { nodes: 1024, edges: 4096, queries: 1200, churn: 200, probe_budget: 128 }
    };
    let ds = synthesize("autotune-bench", sz.nodes, sz.edges, 6, 64, 29);
    let data = DataSource::Dataset(ds.clone());

    // the default hand mapping: what a user gets without tuning
    let mut base = DeploymentSpec::default();
    base.tuning.objective = "throughput".to_string();
    base.tuning.probe_budget = sz.probe_budget;

    let default_serving = Deployment::launch(&base, &data)?;
    let default_qps = drive(default_serving.as_ref(), &sz)?;
    default_serving.shutdown()?;

    let t0 = Instant::now();
    let tuned = Deployment::autotune(&base, &data)?;
    let tune_secs = t0.elapsed().as_secs_f64();
    println!("\n{}", tuned.report.render());

    let tuned_serving = tuned.launch(&data)?;
    let tuned_qps = drive(tuned_serving.as_ref(), &sz)?;
    tuned_serving.shutdown()?;

    let speedup = tuned_qps / default_qps.max(1e-9);
    let winner = tuned.report.rows[0].label.clone();

    let mut t = Table::new(
        "autotuned vs default mapping".to_string(),
        &["mapping", "measured q/s", "speedup"],
    );
    t.row(&["default (plan ×1)".to_string(), format!("{default_qps:.0}"),
            "1.00x".to_string()]);
    t.row(&[winner.clone(), format!("{tuned_qps:.0}"), format!("{speedup:.2}x")]);
    t.print();
    println!(
        "tuning pass: {:.2}s ({} candidates scored, {} pruned, cost model {})",
        tune_secs,
        tuned.report.rows.len(),
        tuned.report.pruned.len(),
        if tuned.report.calibrated { "calibrated" } else { "unit scales" },
    );

    if let Some(path) = json_path {
        let out = format!(
            "{{\n  \"bench\": \"autotune\",\n  \"quick\": {quick},\n  \
             \"nodes\": {}, \"queries\": {},\n  \
             \"default_qps\": {default_qps:.2},\n  \
             \"tuned_qps\": {tuned_qps:.2},\n  \
             \"autotuned_vs_default_speedup\": {speedup:.4},\n  \
             \"winner\": \"{}\",\n  \
             \"candidates\": {},\n  \"calibrated\": {}\n}}\n",
            sz.nodes,
            sz.queries,
            json_escape(&winner),
            tuned.report.rows.len(),
            tuned.report.calibrated,
        );
        std::fs::write(&path, out)?;
        println!("wrote {path}");
    }
    Ok(())
}
