//! Sparse-vs-dense aggregation scaling: density × node-count sweep of
//! the CSR SpMM kernel against the dense (zero-skip) matmul on
//! norm-shaped operands, plus the Cora-scale headline the CI gate reads.
//!
//! ```sh
//! cargo bench --bench spmm_scaling                     # full sweep
//! cargo bench --bench spmm_scaling -- --quick          # CI smoke sizes
//! cargo bench --bench spmm_scaling -- --json out.json  # machine-readable
//! ```
//!
//! The JSON carries `cora_speedup` (SpMM vs dense at 2708 nodes / 5429
//! edges — real Cora density, ~0.2%) and `cora_max_abs_diff`;
//! `bench-smoke` gates `cora_speedup ≥ 3` and exact-tolerance agreement.
//!
//! Every case runs with the CacheG-style RCM locality pass enabled: the
//! norm operand and the feature rows are relabeled through
//! `ops::plan::Reordering` once up front (exactly what a reordered
//! static plan does), so the gate proves the speedup *holds with
//! reordering on*, not just on the original node order.

use std::sync::Arc;

use grannite::bench::{banner, run_bench};
use grannite::cli::Args;
use grannite::engine::{kernels, WorkerPool};
use grannite::graph::Graph;
use grannite::ops::plan::{ReorderMode, Reordering};
use grannite::tensor::Mat;
use grannite::util::{human_bytes, json_escape, Rng};

struct Row {
    nodes: usize,
    edges: usize,
    density: f64,
    dense_us: f64,
    spmm_us: f64,
    max_abs_diff: f32,
    dense_bytes: usize,
    csr_bytes: usize,
}

/// Deterministic synthetic graph with ~`edges` undirected edges.
fn random_graph(nodes: usize, edges: usize, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let raw: Vec<(u32, u32)> = (0..edges * 2)
        .map(|_| (rng.usize(nodes) as u32, rng.usize(nodes) as u32))
        .filter(|&(a, b)| a != b)
        .take(edges)
        .collect();
    Graph::new(nodes, &raw)
}

fn sweep_case(
    pool: &Arc<WorkerPool>,
    nodes: usize,
    edges: usize,
    feat: usize,
    iters: (usize, usize),
) -> Row {
    let g = random_graph(nodes, edges, 0x5eed ^ nodes as u64 ^ edges as u64);
    // CacheG locality pass: relabel every operand through the RCM
    // permutation once up front; both kernels then stream the
    // bandwidth-reduced order. The dense twin is densified from the
    // permuted CSR so the two sides stay exact-value twins.
    let csr0 = g.norm_csr(nodes);
    let reorder = Reordering::compute(ReorderMode::Rcm, &csr0.indptr, &csr0.indices)
        .expect("rcm always yields a permutation");
    let csr = reorder.permute_csr(&csr0);
    let dense = csr.to_dense();
    let density = csr.density();
    let h = reorder.permute_rows(&Mat::from_fn(nodes, feat, |i, j| {
        ((i * 7 + j * 3) % 17) as f32 * 0.1 - 0.8
    }));
    let (w, n) = iters;

    // same row-sharded pool on both sides: this is the engine's actual
    // dense kernel (density-adaptive zero-skip), not a strawman
    let mut dense_out = vec![0.0f32; nodes * feat];
    let dense_stats = run_bench(
        &format!("dense  {nodes:>6}n density {density:.4}"),
        w,
        n,
        || {
            kernels::matmul(
                pool, &dense.data, nodes, nodes, &h.data, feat, &mut dense_out,
            );
        },
    );
    let mut spmm_out = vec![0.0f32; nodes * feat];
    let spmm_stats = run_bench(
        &format!("spmm   {nodes:>6}n nnz {:>8}", csr.nnz()),
        w,
        n,
        || {
            kernels::spmm(
                pool, &csr.indptr, &csr.indices, &csr.values, nodes, &h.data,
                feat, &mut spmm_out,
            );
        },
    );
    let got = Mat::from_vec(nodes, feat, spmm_out.clone());
    let diff = Mat::from_vec(nodes, feat, dense_out.clone()).max_abs_diff(&got);
    Row {
        nodes,
        edges: g.num_edges(),
        density,
        dense_us: dense_stats.mean,
        spmm_us: spmm_stats.mean,
        max_abs_diff: diff,
        dense_bytes: dense.bytes(),
        csr_bytes: csr.bytes(),
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let quick = args.has("quick");
    let json_path = args.options.get("json").cloned();
    banner(if quick {
        "SpMM scaling sweep (density × nodes, quick)"
    } else {
        "SpMM scaling sweep (density × nodes)"
    });

    let pool = Arc::new(WorkerPool::default_parallel());
    let feat = 64;
    let iters = if quick { (1, 3) } else { (3, 12) };

    // density sweep at fixed node count: edges chosen so nnz/n² spans
    // well below and above the SpMM crossover (0.25)
    let mut rows: Vec<Row> = Vec::new();
    let density_nodes = if quick { 512 } else { 1024 };
    for target_density in [0.002f64, 0.01, 0.05, 0.25] {
        let nn = density_nodes as f64 * density_nodes as f64;
        let edges = ((target_density * nn - density_nodes as f64) / 2.0).max(8.0) as usize;
        rows.push(sweep_case(&pool, density_nodes, edges, feat, iters));
    }
    // node-count sweep at citation-graph density (~2 edges per node)
    let node_sweep: &[usize] = if quick { &[512, 2708] } else { &[512, 1024, 2708, 4096] };
    for &n in node_sweep {
        if n == 2708 {
            continue; // the Cora case below covers it exactly
        }
        rows.push(sweep_case(&pool, n, n * 2, feat, iters));
    }
    // THE GATE CASE: Cora-scale — 2708 nodes, 5429 edges, real density
    let cora = sweep_case(&pool, 2708, 5429, feat, iters);
    let cora_speedup = cora.dense_us / cora.spmm_us;
    let cora_diff = cora.max_abs_diff;
    println!(
        "\n  Cora-scale (2708n/{}e, density {:.5}): SpMM {:.2}x over dense, \
         max|Δ| = {:.3e}, mask {} -> {}",
        cora.edges,
        cora.density,
        cora_speedup,
        cora_diff,
        human_bytes(cora.dense_bytes),
        human_bytes(cora.csr_bytes),
    );
    rows.push(cora);

    println!("\n  {:>7} {:>9} {:>9} {:>11} {:>11} {:>8}", "nodes", "edges",
             "density", "dense µs", "spmm µs", "speedup");
    for r in &rows {
        println!(
            "  {:>7} {:>9} {:>9.5} {:>11.1} {:>11.1} {:>7.2}x",
            r.nodes,
            r.edges,
            r.density,
            r.dense_us,
            r.spmm_us,
            r.dense_us / r.spmm_us
        );
    }

    if let Some(path) = json_path {
        let mut out = String::from("{\n");
        out.push_str("  \"bench\": \"spmm_scaling\",\n");
        out.push_str(&format!("  \"quick\": {quick},\n"));
        out.push_str("  \"reorder\": \"rcm\",\n");
        out.push_str(&format!("  \"cora_speedup\": {cora_speedup:.4},\n"));
        out.push_str(&format!("  \"cora_max_abs_diff\": {cora_diff:.6e},\n"));
        out.push_str("  \"rows\": [\n");
        for (i, r) in rows.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"nodes\": {}, \"edges\": {}, \
                 \"density\": {:.6}, \"dense_us\": {:.3}, \"spmm_us\": {:.3}, \
                 \"speedup\": {:.4}, \"max_abs_diff\": {:.6e}, \
                 \"dense_bytes\": {}, \"csr_bytes\": {}}}{}\n",
                json_escape(&format!("n{}_d{:.4}", r.nodes, r.density)),
                r.nodes,
                r.edges,
                r.density,
                r.dense_us,
                r.spmm_us,
                r.dense_us / r.spmm_us,
                r.max_abs_diff,
                r.dense_bytes,
                r.csr_bytes,
                if i + 1 < rows.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        std::fs::write(&path, out)?;
        println!("wrote {path}");
    }
    Ok(())
}
