//! Hot-path profiling bench (EXPERIMENTS.md §Perf): the request-path
//! pieces that run per inference/update, measured in isolation — plus the
//! headline comparison: **planned engine vs reference executor** at Cora
//! scale (2708 nodes), the compile-once/run-many payoff, and the
//! sparse-vs-dense aggregation split.
//!
//! ```sh
//! cargo bench --bench hotpath                     # full run
//! cargo bench --bench hotpath -- --quick          # CI smoke sizes
//! cargo bench --bench hotpath -- --nodes 50000    # node-count sweep
//! cargo bench --bench hotpath -- --json out.json  # machine-readable
//! ```
//!
//! `--nodes N` scales the graph. Above [`DENSE_BYTES_BUDGET`] the
//! dense-adjacency cases (norm rebuild, dense norm@h, ZVC codec, the
//! dense-bound reference/planned comparison) are **skipped with a logged
//! note** instead of allocating n² floats — at those sizes the density is
//! far below the SpMM threshold and the sparse path is the only one that
//! exists in production, so the bench measures CSR construction and the
//! sparse planned engine instead.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use grannite::bench::{banner, run_bench};
use grannite::cli::Args;
use grannite::coordinator::ModelState;
use grannite::engine::pool::par_rows_timed;
use grannite::engine::{kernels, PlanInstance, WorkerPool};
use grannite::graph::datasets::synthesize;
use grannite::graph::{DynamicGraph, Graph};
use grannite::ops::build::{self, Aggregation, GnnDims, QuantScales};
use grannite::ops::exec::{self, Bindings};
use grannite::ops::plan::ExecPlan;
use grannite::telemetry::{SpanKind, Telemetry, TelemetryConfig};
use grannite::tensor::{DensityHint, Mat, Tensor};
use grannite::util::timing::Stats;
use grannite::util::{human_bytes, json_escape, Rng};

/// Ceiling on any single dense capacity² mask the bench will allocate
/// (512 MB of f32) — past it the dense-adjacency cases skip.
const DENSE_BYTES_BUDGET: usize = 512 * 1024 * 1024;

fn gcn_bindings(ds: &grannite::graph::datasets::Dataset, d: GnnDims, seed: u64,
                dense_norm: bool) -> Bindings {
    let mut rng = Rng::new(seed);
    let mut rand = |r: usize, c: usize| {
        Mat::from_fn(r, c, |_, _| (rng.f64() * 0.6 - 0.3) as f32)
    };
    let mut b: Bindings = BTreeMap::new();
    if dense_norm {
        b.insert("norm".into(), Tensor::from_mat(&ds.graph.norm_adjacency(d.n)));
    } else {
        b.insert("norm".into(), Tensor::from_csr(ds.graph.norm_csr(d.n)));
    }
    b.insert("x".into(), Tensor::from_mat(&ds.features));
    b.insert("w1".into(), Tensor::from_mat(&rand(d.f, d.hidden)));
    b.insert("b1".into(), Tensor::from_mat(&rand(1, d.hidden)));
    b.insert("w2".into(), Tensor::from_mat(&rand(d.hidden, d.classes)));
    b.insert("b2".into(), Tensor::from_mat(&rand(1, d.classes)));
    b
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let quick = args.has("quick");
    let json_path = args.options.get("json").cloned();
    let nodes = args.usize_opt("nodes", 2708)?;
    let edges = args.usize_opt(
        "edges",
        if nodes == 2708 { 5429 } else { nodes * 2 },
    )?;
    let features = if nodes == 2708 { 1433 } else { 256.min(nodes) };
    let capacity = nodes + (nodes / 10).max(1);
    banner(&format!(
        "hot-path microbenchmarks (L3{}, {nodes} nodes / {edges} edges)",
        if quick { ", quick" } else { "" }
    ));

    // Dense-adjacency gate: density + bytes of one capacity² f32 mask.
    let density = (2.0 * edges as f64 + nodes as f64) / (nodes as f64 * nodes as f64);
    let dense_bytes = capacity * capacity * 4;
    let dense_ok = dense_bytes <= DENSE_BYTES_BUDGET;
    if !dense_ok {
        println!(
            "note: skipping dense-adjacency cases — a {capacity}² mask needs {} \
             (> {} budget) and density {density:.5} is far below the SpMM \
             threshold {}; running the sparse path only",
            human_bytes(dense_bytes),
            human_bytes(DENSE_BYTES_BUDGET),
            build::SPMM_DENSITY_THRESHOLD,
        );
    }

    let mut cases: Vec<(String, Stats)> = Vec::new();
    let mut record = |name: &str, stats: Stats| {
        cases.push((name.to_string(), stats));
    };
    // (warmup, iters) per cost tier, shrunk in --quick mode
    let tier = |w: usize, n: usize| if quick { (1, 3.min(n)) } else { (w, n) };

    // 1. GrAd incremental mask update
    let ds = synthesize("hot", nodes, edges, 7, features, 1);
    let mut dg = DynamicGraph::new(&ds.graph, capacity)?;
    if dense_ok {
        let _ = dg.norm(); // materialize so updates take the in-place path
    }
    let mut rng = Rng::new(7);
    let (w, n) = tier(10, 200);
    record(
        "grad_update",
        run_bench(
            &format!("GrAd add+remove edge (cap {capacity})"),
            w,
            n,
            || {
                let u = rng.usize(nodes);
                let v = (u + 1 + rng.usize(nodes - 2)) % nodes;
                let _ = dg.add_edge(u.min(v), u.max(v));
                let _ = dg.remove_edge(u.min(v), u.max(v));
            },
        ),
    );

    let g: Graph = ds.graph.clone();

    // 2. norm construction: full dense rebuild (what GrAd avoids) vs the
    //    O(n + m) CSR build the sparse path ships
    if dense_ok {
        let (w, n) = tier(2, 20);
        record(
            "norm_rebuild",
            run_bench(&format!("full PreG norm rebuild ({nodes}²)"), w, n, || {
                std::hint::black_box(g.norm_adjacency(capacity));
            }),
        );
    }
    let (w, n) = tier(3, 30);
    record(
        "norm_csr_build",
        run_bench("PreG norm CSR build (O(n+m))", w, n, || {
            std::hint::black_box(g.norm_csr(capacity));
        }),
    );

    // 3. CacheG binding hit vs miss
    let mut state = ModelState::from_dataset(ds.clone(), capacity)?;
    let binding_key = if dense_ok { "norm_pad" } else { "norm_csr_pad" };
    let _ = state.binding(binding_key, "gcn"); // warm
    let (w, n) = tier(5, 100);
    record(
        "cacheg_hit",
        run_bench(
            &format!("binding({binding_key:?}) CacheG hit"),
            w,
            n,
            || state.binding(binding_key, "gcn").unwrap(),
        ),
    );

    // 4. aggregation kernels: dense norm@h vs CSR SpMM
    let h = Mat::from_fn(nodes, 64, |i, j| ((i * 7 + j) % 13) as f32 * 0.1);
    let csr = g.norm_csr(nodes);
    let pool = Arc::new(WorkerPool::default_parallel());
    let mut spmm_out = vec![0.0f32; nodes * 64];
    let (w, n) = tier(3, 30);
    let spmm_stats = run_bench(
        &format!("CSR SpMM norm@h ({nodes}², nnz {})", csr.nnz()),
        w,
        n,
        || {
            kernels::spmm(
                &pool, &csr.indptr, &csr.indices, &csr.values, nodes,
                &h.data, 64, &mut spmm_out,
            );
        },
    );
    record("spmm_matmul", spmm_stats.clone());
    if dense_ok {
        let norm = g.norm_adjacency(nodes);
        let (w, n) = tier(3, 30);
        let dense_stats = run_bench(
            &format!("dense zero-skip matmul norm@h ({nodes}²x64)"),
            w,
            n,
            || norm.matmul(&h),
        );
        record("sparse_matmul", dense_stats.clone());
        println!(
            "  aggregation: SpMM {:.2}x over the dense zero-skip kernel",
            dense_stats.mean / spmm_stats.mean
        );

        // 5. ZVC codec at mask scale (dense-mask path only)
        let z = grannite::graph::sparsity::Zvc::compress_mat(&norm);
        println!(
            "  norm ZVC: {} -> {} ({:.1}x); CSR: {} ({:.1}x)",
            human_bytes(z.dense_bytes()),
            human_bytes(z.bytes()),
            z.dense_bytes() as f64 / z.bytes() as f64,
            human_bytes(csr.bytes()),
            z.dense_bytes() as f64 / csr.bytes() as f64,
        );
        let (w, n) = tier(2, 20);
        record(
            "zvc_compress",
            run_bench(&format!("ZVC compress norm ({nodes}²)"), w, n, || {
                grannite::graph::sparsity::Zvc::compress_mat(&norm)
            }),
        );
    }

    // 5b. SIMD microkernel vs scalar oracle: the same dense matmul
    //     ({nodes}×256 @ 256×256, density hint NoSkip so neither path
    //     probes) through both dispatch flags — register blocking and
    //     k-panel tiling must pay for themselves, gated in CI.
    let mk = 256usize;
    let a = Mat::from_fn(nodes, mk, |i, j| ((i * 31 + j * 7) % 17) as f32 * 0.125 - 1.0);
    let wmat = Mat::from_fn(mk, mk, |i, j| ((i * 13 + j * 3) % 11) as f32 * 0.25 - 1.25);
    let mut mm_out = vec![0.0f32; nodes * mk];
    let (w, n) = tier(2, 15);
    let scalar_stats = run_bench(
        &format!("scalar matmul {nodes}x{mk} @ {mk}x{mk}"),
        w,
        n,
        || {
            kernels::matmul_with(
                &pool, &a.data, nodes, mk, &wmat.data, mk, &mut mm_out,
                DensityHint::NoSkip, false,
            );
        },
    );
    record("scalar_matmul", scalar_stats.clone());
    let simd_stats = run_bench(
        &format!("SIMD matmul {nodes}x{mk} @ {mk}x{mk}"),
        w,
        n,
        || {
            kernels::matmul_with(
                &pool, &a.data, nodes, mk, &wmat.data, mk, &mut mm_out,
                DensityHint::NoSkip, true,
            );
        },
    );
    record("simd_matmul", simd_stats.clone());
    let simd_speedup = scalar_stats.mean / simd_stats.mean;
    println!("  SIMD microkernel: {simd_speedup:.2}x over the scalar oracle");

    // 5c. degree-skew lane balance: a power-law row distribution (hub
    //     rows up front, 1/i tail) driven through the row-count
    //     dispenser vs the nnz-balanced one. worst-lane/mean busy time
    //     is the wall-clock waste factor — binned must stay near 1.
    let mut pl_indptr = vec![0u32];
    let mut pl_nnz = 0usize;
    for i in 0..nodes {
        pl_nnz += (nodes / (i + 1)).clamp(1, 4096);
        pl_indptr.push(pl_nnz as u32);
    }
    let busy: Vec<AtomicU64> =
        (0..pool.threads()).map(|_| AtomicU64::new(0)).collect();
    let skew_ratio = |indptr: Option<&[u32]>| -> f64 {
        for b in &busy {
            b.store(0, Ordering::Relaxed);
        }
        par_rows_timed(
            &pool,
            nodes,
            1,
            indptr,
            kernels::DEGREE_BINS_DEFAULT,
            &|r0, r1| {
                // aggregation stand-in: work strictly ∝ row nnz
                let mut acc = 0.0f32;
                for r in r0..r1 {
                    let deg = (pl_indptr[r + 1] - pl_indptr[r]) as usize;
                    for t in 0..deg * 64 {
                        acc += ((t ^ r) as f32).sqrt();
                    }
                }
                std::hint::black_box(acc);
            },
            &busy,
        );
        let ns: Vec<f64> =
            busy.iter().map(|b| b.load(Ordering::Relaxed) as f64).collect();
        let mean = ns.iter().sum::<f64>() / ns.len().max(1) as f64;
        let worst = ns.iter().cloned().fold(0.0, f64::max);
        if mean <= 0.0 { 1.0 } else { worst / mean }
    };
    let skew_uniform = skew_ratio(None);
    let skew_binned = skew_ratio(Some(&pl_indptr));
    println!(
        "  degree skew ({pl_nnz} nnz over {nodes} rows): worst-lane/mean \
         {skew_uniform:.2}x row-balanced -> {skew_binned:.2}x nnz-balanced"
    );

    // 6. THE HEADLINE: planned engine vs reference executor, GCN
    //    end-to-end inference (same graph, same bindings) — plus the
    //    sparse-aggregation plan, which is the production default.
    let d = GnnDims::model(nodes, edges, features, 7);
    let mut headline: Option<(f64, f32)> = None; // (speedup, diff)
    let mut sparse_vs_dense: Option<f64> = None;
    let gcn_sparse = build::gcn_stagr_with(d, "stagr", Aggregation::Sparse);
    let sparse_bindings = gcn_bindings(&ds, d, 42, false);
    let sparse_plan = Arc::new(ExecPlan::compile(&gcn_sparse)?);
    let mut sparse_inst =
        PlanInstance::new(Arc::clone(&sparse_plan), Arc::clone(&pool));
    sparse_inst.run(&sparse_bindings)?; // warm
    let (w, n) = tier(2, 10);
    let sparse_exec = run_bench(
        &format!("planned SpMM ExecPlan::run ({nodes}-node GCN e2e)"),
        w,
        n,
        || sparse_inst.run(&sparse_bindings).unwrap(),
    );
    record("planned_exec_sparse", sparse_exec.clone());

    // 6b. the same sparse hot path with telemetry ENABLED: profiler
    //     attached to the plan, plus the per-round recorder calls the
    //     shard loop makes (engine-round span + per-op span drain). The
    //     ratio below is the advertised overhead bound, gated in CI.
    let telemetry = Telemetry::new(TelemetryConfig {
        enabled: true,
        ring_capacity: 4096,
        sample_rate: 1.0,
    });
    let recorder = telemetry.recorder(0);
    let mut traced_inst =
        PlanInstance::new(Arc::clone(&sparse_plan), Arc::clone(&pool));
    traced_inst.attach_profiler(telemetry.plan_profiler(0, &sparse_plan));
    traced_inst.run(&sparse_bindings)?; // warm
    let mut trace_id = 0u64;
    let (w, n) = tier(2, 10);
    let traced_exec = run_bench(
        &format!("planned SpMM + telemetry on ({nodes}-node GCN e2e)"),
        w,
        n,
        || {
            trace_id += 1;
            let t0 = recorder.now_us();
            traced_inst.run(&sparse_bindings).unwrap();
            let dur = recorder.now_us() - t0;
            recorder.record(trace_id, SpanKind::EngineRound, "round", t0, dur, 1);
            let mut off = t0;
            for obs in telemetry.drain_last_round(0) {
                recorder.record(trace_id, SpanKind::Op, obs.kind, off, obs.dur_us, 0);
                off += obs.dur_us;
            }
        },
    );
    record("planned_exec_sparse_telemetry", traced_exec.clone());
    let telemetry_overhead = traced_exec.p50 / sparse_exec.p50;
    let (spans_total, _) = telemetry.span_counts();
    println!(
        "  telemetry overhead: {telemetry_overhead:.3}x on the sparse hot \
         path ({spans_total} spans recorded)"
    );

    if dense_ok {
        let gcn = build::gcn_stagr(d, "stagr");
        let bindings = gcn_bindings(&ds, d, 42, true);
        let (w, n) = tier(2, 10);
        let ref_stats = run_bench(
            &format!("reference exec::execute ({nodes}-node GCN e2e)"),
            w,
            n,
            || exec::execute_mat(&gcn, &bindings).unwrap(),
        );
        record("reference_exec", ref_stats.clone());

        let plan = Arc::new(ExecPlan::compile(&gcn)?);
        println!(
            "  plan: {} steps ({} ops fused away), arena {} vs {} unshared",
            plan.num_steps(),
            plan.fused_away,
            human_bytes(plan.arena_bytes()),
            human_bytes(plan.unshared_bytes()),
        );
        let mut inst = PlanInstance::new(Arc::clone(&plan), Arc::clone(&pool));
        inst.run(&bindings)?; // compile-adjacent warmup: arena + weight caches
        let plan_stats = run_bench(
            &format!("planned ExecPlan::run ({nodes}-node GCN e2e)"),
            w,
            n,
            || inst.run(&bindings).unwrap(),
        );
        record("planned_exec", plan_stats.clone());

        let speedup = ref_stats.mean / plan_stats.mean;
        let want = exec::execute_mat(&gcn, &bindings)?;
        let got = inst.output_mat(0)?;
        let diff = want.max_abs_diff(&got);
        println!(
            "  planned vs reference: {speedup:.2}x speedup, max|Δ| = {diff:.3e}"
        );
        headline = Some((speedup, diff));

        let s = plan_stats.mean / sparse_exec.mean;
        let sdiff = want.max_abs_diff(&sparse_inst.output_mat(0)?);
        println!(
            "  sparse vs dense aggregation: {s:.2}x e2e, max|Δ| = {sdiff:.3e}"
        );
        sparse_vs_dense = Some(s);
        anyhow::ensure!(sdiff < 1e-4, "sparse plan drifted from the oracle");
    }

    // 7. QuantGr INT8: planned i8×i8→i32 kernels vs the reference
    //    executor's rounded-f32 emulation (smaller scale — the reference
    //    QMatMul is an O(n·f·h) f64 triple loop).
    let mut int8_speedup: Option<f64> = None;
    if dense_ok {
        let qd = GnnDims::model(512, 2048, 256, 7);
        let qds = synthesize("hot-q", qd.n, qd.m, qd.classes, qd.f, 3);
        let qg = build::gcn_quant(qd, QuantScales::default());
        let mut qb = gcn_bindings(&qds, qd, 17, true);
        let mut qrng = Rng::new(23);
        for (name, r, c) in [("w1q", qd.f, qd.hidden), ("w2q", qd.hidden, qd.classes)] {
            let ints = Mat::from_fn(r, c, |_, _| (qrng.usize(255) as i32 - 127) as f32);
            qb.insert(name.into(), Tensor::from_mat(&ints));
        }
        let (w, n) = tier(2, 10);
        let qref = run_bench("reference exec (512-node INT8 GCN)", w, n, || {
            exec::execute_mat(&qg, &qb).unwrap()
        });
        record("reference_int8", qref.clone());
        let qplan = Arc::new(ExecPlan::compile(&qg)?);
        let mut qinst = PlanInstance::new(qplan, Arc::clone(&pool));
        qinst.run(&qb)?;
        let qfast = run_bench("planned INT8 ExecPlan::run (512-node)", w, n, || {
            qinst.run(&qb).unwrap()
        });
        record("planned_int8", qfast.clone());
        let qdiff = exec::execute_mat(&qg, &qb)?.max_abs_diff(&qinst.output_mat(0)?);
        println!(
            "  planned INT8 vs reference: {:.2}x speedup, max|Δ| = {qdiff:.3e}",
            qref.mean / qfast.mean
        );
        int8_speedup = Some(qref.mean / qfast.mean);
    }

    // 8. end-to-end through the artifact runtime (only with artifacts)
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.toml").exists() {
        let mut c = grannite::coordinator::Coordinator::open(dir, "cora")?;
        let name = "gcn_stagr_cora";
        let _ = c.infer(name)?; // plan compile + warm
        let (w, n) = tier(2, 10);
        record(
            "runtime_infer",
            run_bench("Runtime infer gcn_stagr_cora e2e", w, n, || {
                c.infer(name).unwrap()
            }),
        );
    } else {
        println!("(skipping artifact runtime hot path: artifacts/ missing)");
    }

    if let Some(path) = json_path {
        let mut out = String::from("{\n");
        out.push_str("  \"bench\": \"hotpath\",\n");
        out.push_str(&format!("  \"quick\": {quick},\n"));
        out.push_str(&format!("  \"nodes\": {nodes},\n"));
        out.push_str(&format!("  \"dense_cases\": {dense_ok},\n"));
        if let Some((speedup, diff)) = headline {
            out.push_str(&format!(
                "  \"plan_vs_reference_speedup\": {speedup:.4},\n"
            ));
            out.push_str(&format!(
                "  \"plan_vs_reference_max_abs_diff\": {diff:.6e},\n"
            ));
        }
        if let Some(s) = sparse_vs_dense {
            out.push_str(&format!(
                "  \"sparse_vs_dense_agg_speedup\": {s:.4},\n"
            ));
        }
        out.push_str(&format!(
            "  \"telemetry_overhead_ratio\": {telemetry_overhead:.4},\n"
        ));
        out.push_str(&format!("  \"simd_speedup\": {simd_speedup:.4},\n"));
        out.push_str(&format!(
            "  \"skew_balance_uniform\": {skew_uniform:.4},\n"
        ));
        out.push_str(&format!(
            "  \"skew_balance_binned\": {skew_binned:.4},\n"
        ));
        if let Some(q) = int8_speedup {
            out.push_str(&format!(
                "  \"int8_plan_vs_reference_speedup\": {q:.4},\n"
            ));
        }
        out.push_str("  \"cases\": [\n");
        for (i, (name, s)) in cases.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"n\": {}, \"mean_us\": {:.3}, \
                 \"p50_us\": {:.3}, \"p95_us\": {:.3}, \"max_us\": {:.3}}}{}\n",
                json_escape(name),
                s.n,
                s.mean,
                s.p50,
                s.p95,
                s.max,
                if i + 1 < cases.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        std::fs::write(&path, out)?;
        println!("wrote {path}");
    }
    Ok(())
}
