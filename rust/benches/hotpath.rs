//! Hot-path profiling bench (EXPERIMENTS.md §Perf): the request-path
//! pieces that run per inference/update, measured in isolation — plus the
//! headline comparison: **planned engine vs reference executor** at Cora
//! scale (2708 nodes), the compile-once/run-many payoff.
//!
//! ```sh
//! cargo bench --bench hotpath                     # full run
//! cargo bench --bench hotpath -- --quick          # CI smoke sizes
//! cargo bench --bench hotpath -- --json out.json  # machine-readable
//! ```

use std::collections::BTreeMap;
use std::sync::Arc;

use grannite::bench::{banner, run_bench};
use grannite::cli::Args;
use grannite::coordinator::ModelState;
use grannite::engine::{PlanInstance, WorkerPool};
use grannite::graph::datasets::synthesize;
use grannite::graph::{DynamicGraph, Graph};
use grannite::ops::build::{self, GnnDims, QuantScales};
use grannite::ops::exec::{self, Bindings};
use grannite::ops::plan::ExecPlan;
use grannite::tensor::{Mat, Tensor};
use grannite::util::timing::Stats;
use grannite::util::{json_escape, Rng};

fn gcn_bindings(ds: &grannite::graph::datasets::Dataset, d: GnnDims, seed: u64) -> Bindings {
    let mut rng = Rng::new(seed);
    let mut rand = |r: usize, c: usize| {
        Mat::from_fn(r, c, |_, _| (rng.f64() * 0.6 - 0.3) as f32)
    };
    let mut b: Bindings = BTreeMap::new();
    b.insert("norm".into(), Tensor::from_mat(&ds.graph.norm_adjacency(d.n)));
    b.insert("x".into(), Tensor::from_mat(&ds.features));
    b.insert("w1".into(), Tensor::from_mat(&rand(d.f, d.hidden)));
    b.insert("b1".into(), Tensor::from_mat(&rand(1, d.hidden)));
    b.insert("w2".into(), Tensor::from_mat(&rand(d.hidden, d.classes)));
    b.insert("b2".into(), Tensor::from_mat(&rand(1, d.classes)));
    b
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let quick = args.has("quick");
    let json_path = args.options.get("json").cloned();
    banner(if quick {
        "hot-path microbenchmarks (L3, quick)"
    } else {
        "hot-path microbenchmarks (L3)"
    });

    let mut cases: Vec<(String, Stats)> = Vec::new();
    let mut record = |name: &str, stats: Stats| {
        cases.push((name.to_string(), stats));
    };
    // (warmup, iters) per cost tier, shrunk in --quick mode
    let tier = |w: usize, n: usize| if quick { (1, 3.min(n)) } else { (w, n) };

    // 1. GrAd incremental mask update at Cora scale
    let ds = synthesize("hot", 2708, 5429, 7, 1433, 1);
    let mut dg = DynamicGraph::new(&ds.graph, 3000)?;
    let mut rng = Rng::new(7);
    let (w, n) = tier(10, 200);
    record(
        "grad_update",
        run_bench("GrAd add+remove edge (cap 3000)", w, n, || {
            let u = rng.usize(2708);
            let v = (u + 1 + rng.usize(2706)) % 2708;
            let _ = dg.add_edge(u.min(v), u.max(v));
            let _ = dg.remove_edge(u.min(v), u.max(v));
        }),
    );

    // 2. full norm rebuild (what GrAd avoids)
    let g: Graph = ds.graph.clone();
    let (w, n) = tier(2, 20);
    record(
        "norm_rebuild",
        run_bench("full PreG norm rebuild (2708²)", w, n, || {
            std::hint::black_box(g.norm_adjacency(3000));
        }),
    );

    // 3. CacheG binding hit vs miss
    let mut state = ModelState::from_dataset(ds.clone(), 3000)?;
    let _ = state.binding("norm_pad", "gcn"); // warm
    let (w, n) = tier(5, 100);
    record(
        "cacheg_hit",
        run_bench("binding('norm_pad') CacheG hit", w, n, || {
            state.binding("norm_pad", "gcn").unwrap()
        }),
    );

    // 4. density-adaptive matmul (sparse mask lhs → zero-skip kernel)
    let norm = g.norm_adjacency(2708);
    let h = Mat::from_fn(2708, 64, |i, j| ((i * 7 + j) % 13) as f32 * 0.1);
    let (w, n) = tier(3, 30);
    record(
        "sparse_matmul",
        run_bench("sparse-aware matmul norm@h (2708²x64)", w, n, || {
            norm.matmul(&h)
        }),
    );

    // 5. ZVC codec at mask scale
    let z = grannite::graph::sparsity::Zvc::compress_mat(&norm);
    println!(
        "  norm ZVC: {} -> {} ({:.1}x)",
        grannite::util::human_bytes(z.dense_bytes()),
        grannite::util::human_bytes(z.bytes()),
        z.dense_bytes() as f64 / z.bytes() as f64
    );
    let (w, n) = tier(2, 20);
    record(
        "zvc_compress",
        run_bench("ZVC compress norm (2708²)", w, n, || {
            grannite::graph::sparsity::Zvc::compress_mat(&norm)
        }),
    );

    // 6. THE HEADLINE: planned engine vs reference executor, Cora-scale
    //    GCN end-to-end inference (same graph, same bindings).
    let d = GnnDims::model(2708, 5429, 1433, 7);
    let gcn = build::gcn_stagr(d, "stagr");
    let bindings = gcn_bindings(&ds, d, 42);
    let (w, n) = tier(2, 10);
    let ref_stats = run_bench("reference exec::execute (Cora GCN e2e)", w, n, || {
        exec::execute_mat(&gcn, &bindings).unwrap()
    });
    record("reference_exec", ref_stats.clone());

    let plan = Arc::new(ExecPlan::compile(&gcn)?);
    println!(
        "  plan: {} steps ({} ops fused away), arena {} vs {} unshared",
        plan.num_steps(),
        plan.fused_away,
        grannite::util::human_bytes(plan.arena_bytes()),
        grannite::util::human_bytes(plan.unshared_bytes()),
    );
    let pool = Arc::new(WorkerPool::default_parallel());
    let mut inst = PlanInstance::new(Arc::clone(&plan), pool);
    inst.run(&bindings)?; // compile-adjacent warmup: arena + weight caches
    let plan_stats = run_bench("planned ExecPlan::run (Cora GCN e2e)", w, n, || {
        inst.run(&bindings).unwrap()
    });
    record("planned_exec", plan_stats.clone());

    let speedup = ref_stats.mean / plan_stats.mean;
    let want = exec::execute_mat(&gcn, &bindings)?;
    let got = inst.output_mat(0)?;
    let diff = want.max_abs_diff(&got);
    println!(
        "  planned vs reference: {speedup:.2}x speedup, max|Δ| = {diff:.3e}"
    );

    // 7. QuantGr INT8: planned i8×i8→i32 kernels vs the reference
    //    executor's rounded-f32 emulation (smaller scale — the reference
    //    QMatMul is an O(n·f·h) f64 triple loop).
    let qd = GnnDims::model(512, 2048, 256, 7);
    let qds = synthesize("hot-q", qd.n, qd.m, qd.classes, qd.f, 3);
    let qg = build::gcn_quant(qd, QuantScales::default());
    let mut qb = gcn_bindings(&qds, qd, 17);
    let mut qrng = Rng::new(23);
    for (name, r, c) in [("w1q", qd.f, qd.hidden), ("w2q", qd.hidden, qd.classes)] {
        let ints = Mat::from_fn(r, c, |_, _| (qrng.usize(255) as i32 - 127) as f32);
        qb.insert(name.into(), Tensor::from_mat(&ints));
    }
    let (w, n) = tier(2, 10);
    let qref = run_bench("reference exec (512-node INT8 GCN)", w, n, || {
        exec::execute_mat(&qg, &qb).unwrap()
    });
    record("reference_int8", qref.clone());
    let qplan = Arc::new(ExecPlan::compile(&qg)?);
    let mut qinst =
        PlanInstance::new(qplan, Arc::new(WorkerPool::default_parallel()));
    qinst.run(&qb)?;
    let qfast = run_bench("planned INT8 ExecPlan::run (512-node)", w, n, || {
        qinst.run(&qb).unwrap()
    });
    record("planned_int8", qfast.clone());
    let qdiff = exec::execute_mat(&qg, &qb)?.max_abs_diff(&qinst.output_mat(0)?);
    println!(
        "  planned INT8 vs reference: {:.2}x speedup, max|Δ| = {qdiff:.3e}",
        qref.mean / qfast.mean
    );

    // 8. end-to-end through the artifact runtime (only with artifacts)
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.toml").exists() {
        let mut c = grannite::coordinator::Coordinator::open(dir, "cora")?;
        let name = "gcn_stagr_cora";
        let _ = c.infer(name)?; // plan compile + warm
        let (w, n) = tier(2, 10);
        record(
            "runtime_infer",
            run_bench("Runtime infer gcn_stagr_cora e2e", w, n, || {
                c.infer(name).unwrap()
            }),
        );
    } else {
        println!("(skipping artifact runtime hot path: artifacts/ missing)");
    }

    if let Some(path) = json_path {
        let mut out = String::from("{\n");
        out.push_str("  \"bench\": \"hotpath\",\n");
        out.push_str(&format!("  \"quick\": {quick},\n"));
        out.push_str(&format!(
            "  \"plan_vs_reference_speedup\": {speedup:.4},\n"
        ));
        out.push_str(&format!(
            "  \"plan_vs_reference_max_abs_diff\": {diff:.6e},\n"
        ));
        out.push_str(&format!(
            "  \"int8_plan_vs_reference_speedup\": {:.4},\n",
            qref.mean / qfast.mean
        ));
        out.push_str("  \"cases\": [\n");
        for (i, (name, s)) in cases.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"n\": {}, \"mean_us\": {:.3}, \
                 \"p50_us\": {:.3}, \"p95_us\": {:.3}, \"max_us\": {:.3}}}{}\n",
                json_escape(name),
                s.n,
                s.mean,
                s.p50,
                s.p95,
                s.max,
                if i + 1 < cases.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        std::fs::write(&path, out)?;
        println!("wrote {path}");
    }
    Ok(())
}
