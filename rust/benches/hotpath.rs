//! Hot-path profiling bench (EXPERIMENTS.md §Perf): the request-path
//! pieces that run per inference/update, measured in isolation.
use grannite::bench::{banner, run_bench};
use grannite::coordinator::ModelState;
use grannite::graph::datasets::synthesize;
use grannite::graph::{DynamicGraph, Graph};
use grannite::tensor::Mat;
use grannite::util::Rng;

fn main() -> anyhow::Result<()> {
    banner("hot-path microbenchmarks (L3)");

    // 1. GrAd incremental mask update at Cora scale
    let ds = synthesize("hot", 2708, 5429, 7, 64, 1);
    let mut dg = DynamicGraph::new(&ds.graph, 3000)?;
    let mut rng = Rng::new(7);
    run_bench("GrAd add+remove edge (cap 3000)", 10, 200, || {
        let u = rng.usize(2708);
        let v = (u + 1 + rng.usize(2706)) % 2708;
        let _ = dg.add_edge(u.min(v), u.max(v));
        let _ = dg.remove_edge(u.min(v), u.max(v));
    });

    // 2. full norm rebuild (what GrAd avoids)
    let g: Graph = ds.graph.clone();
    run_bench("full PreG norm rebuild (2708²)", 2, 20, || {
        std::hint::black_box(g.norm_adjacency(3000));
    });

    // 3. CacheG binding hit vs miss
    let mut state = ModelState::from_dataset(ds.clone(), 3000)?;
    let _ = state.binding("norm_pad", "gcn"); // warm
    run_bench("binding('norm_pad') CacheG hit", 5, 100, || {
        state.binding("norm_pad", "gcn").unwrap()
    });

    // 4. reference-executor aggregation matmul (CPU fallback path)
    let norm = g.norm_adjacency(2708);
    let h = Mat::from_fn(2708, 64, |i, j| ((i * 7 + j) % 13) as f32 * 0.1);
    run_bench("sparse-aware matmul norm@h (2708²x64)", 3, 30, || {
        norm.matmul(&h)
    });

    // 5. ZVC codec at mask scale
    let z = grannite::graph::sparsity::Zvc::compress_mat(&norm);
    println!(
        "  norm ZVC: {} -> {} ({:.1}x)",
        grannite::util::human_bytes(z.dense_bytes()),
        grannite::util::human_bytes(z.bytes()),
        z.dense_bytes() as f64 / z.bytes() as f64
    );
    run_bench("ZVC compress norm (2708²)", 2, 20, || {
        grannite::graph::sparsity::Zvc::compress_mat(&norm)
    });

    // 6. PJRT end-to-end (only with artifacts)
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.toml").exists() {
        let mut c = grannite::coordinator::Coordinator::open(dir, "cora")?;
        let name = "gcn_stagr_cora";
        let _ = c.infer(name)?; // compile+warm
        run_bench("PJRT infer gcn_stagr_cora e2e", 2, 10, || {
            c.infer(name).unwrap()
        });
    } else {
        println!("(skipping PJRT hot path: artifacts/ missing)");
    }
    Ok(())
}
