//! Accuracy table over the real PJRT artifacts: the paper's quality-loss
//! claims (QuantGr / GrAx1-3 "negligible loss") measured on real numerics.
//! Requires `make artifacts`; prints a skip notice otherwise.
use grannite::bench::banner;
use grannite::coordinator::Coordinator;
use grannite::util::Table;

fn main() -> anyhow::Result<()> {
    banner("Accuracy — PJRT execution of every artifact");
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.toml").exists() {
        println!("artifacts/ missing — run `make artifacts` first (skipping)");
        return Ok(());
    }
    for dataset in ["cora", "citeseer"] {
        let mut c = Coordinator::open(dir, dataset)?;
        let mut t = Table::new(
            format!("accuracy on {dataset} twin"),
            &["artifact", "test acc", "latency"],
        );
        let names: Vec<String> = c
            .runtime
            .artifact_names()
            .iter()
            .filter(|n| n.ends_with(dataset) && !n.contains("_ev_"))
            .map(|s| s.to_string())
            .collect();
        for name in names {
            let t0 = std::time::Instant::now();
            match c.evaluate(&name) {
                Ok(acc) => {
                    t.row(&[
                        name.clone(),
                        format!("{acc:.3}"),
                        grannite::util::human_us(t0.elapsed().as_secs_f64() * 1e6),
                    ]);
                }
                Err(e) => {
                    t.row(&[name.clone(), format!("error: {e:#}"), "-".into()]);
                }
            }
        }
        t.print();
    }
    Ok(())
}
