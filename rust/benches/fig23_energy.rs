//! Regenerates paper Fig. 23: normalized energy per inference.
use grannite::bench::{banner, figures};

fn main() {
    banner("Fig. 23 — energy comparison");
    figures::fig23().print();
    figures::graphsplit_ablation(&grannite::graph::datasets::CORA).print();
}
