//! Regenerates paper Fig. 22: CPU / GPU / NPU model throughput.
use grannite::bench::{banner, figures};
use grannite::graph::datasets;

fn main() {
    banner("Fig. 22 — device comparison");
    figures::fig22(&datasets::CORA).print();
    figures::fig22(&datasets::CITESEER).print();
}
