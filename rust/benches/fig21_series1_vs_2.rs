//! Regenerates paper Fig. 21: Series-1 vs Series-2 NPU GCN throughput.
use grannite::bench::{banner, figures};

fn main() {
    banner("Fig. 21 — Series 1 vs Series 2");
    figures::fig21().print();
}
