//! Fleet scaling sweep: serve the same query load from 1→8 shards and
//! report measured throughput next to the planner's estimated round cost
//! and the halo traffic each configuration pays.
//!
//! Two sweeps: homogeneous (N × Series-2 NPU — the clean scaling curve)
//! and heterogeneous (NPU2/NPU1/iGPU/CPU zoo — what the cost-model
//! placement is for). Every configuration is one `DeploymentSpec`
//! launched through `Deployment::launch` with the artifact-free `local`
//! engine, whose per-query work is proportional to the shard's owned
//! nodes, so wall-clock scaling tracks the partition, not the execution
//! backend.
//!
//! ```sh
//! cargo bench --bench fleet_scaling                     # full sweep
//! cargo bench --bench fleet_scaling -- --quick          # CI smoke sizes
//! cargo bench --bench fleet_scaling -- --json out.json  # machine-readable
//! ```

use std::time::Instant;

use grannite::bench::banner;
use grannite::cli::Args;
use grannite::graph::datasets::synthesize;
use grannite::serve::{
    Deployment, DeploymentSpec, EngineRegistry, EngineSpec, Serving, Topology,
};
use grannite::server::Update;
use grannite::util::{human_bytes, human_us, json_escape, Rng, Table};

struct Sizes {
    nodes: usize,
    edges: usize,
    queries: usize,
    churn: usize,
}

struct Row {
    shards: usize,
    label: String,
    est_round_us: f64,
    cut_edges: usize,
    halo_bytes_per_round: usize,
    qps: f64,
}

fn spec_for(topology: Topology, capacity: usize) -> DeploymentSpec {
    DeploymentSpec {
        engine: EngineSpec::named("local"),
        topology,
        capacity,
        ..DeploymentSpec::default()
    }
}

fn drive(serving: &dyn Serving, sz: &Sizes) -> anyhow::Result<f64> {
    // mixed load: a burst of GrAd churn, then a query storm
    let mut rng = Rng::new(11);
    for _ in 0..sz.churn {
        let u = rng.usize(sz.nodes);
        let v = (u + 1 + rng.usize(sz.nodes - 1)) % sz.nodes;
        serving.update(Update::AddEdge(u.min(v), u.max(v)))?;
    }
    let t0 = Instant::now();
    let pending: Vec<_> = (0..sz.queries)
        .map(|_| serving.query(Some(rng.usize(sz.nodes))))
        .collect::<anyhow::Result<_>>()?;
    for rx in pending {
        rx.recv()?.map_err(anyhow::Error::msg)?;
    }
    Ok(sz.queries as f64 / t0.elapsed().as_secs_f64())
}

fn sweep(
    title: &str,
    configs: &[(String, Topology)],
    sz: &Sizes,
    rows_out: &mut Vec<Row>,
) -> anyhow::Result<()> {
    let ds = synthesize("fleet-bench", sz.nodes, sz.edges, 6, 64, 5);
    let mut t = Table::new(
        title.to_string(),
        &[
            "shards",
            "devices",
            "est round",
            "cut edges",
            "halo/round",
            "measured q/s",
            "p50",
            "p99",
            "halo total",
        ],
    );
    let mut baseline: Option<(f64, f64)> = None; // (qps, est_round_us)
    for (label, topology) in configs {
        let spec = spec_for(topology.clone(), sz.nodes + 64);
        let plan = Deployment::plan(&spec, &ds)?;
        let est_round = plan.est_round_us;
        let cut = plan.cut_edges;
        let halo_round = plan.halo_bytes_per_round;
        let serving = Deployment::launch_at(&EngineRegistry::builtin(), &spec, &ds,
                                            None, Some(plan.clone()))?;
        let qps = drive(serving.as_ref(), sz)?;
        let agg = serving.metrics();
        let (p50, p99) = agg
            .latency
            .as_ref()
            .map(|l| (human_us(l.p50), human_us(l.p99)))
            .unwrap_or_else(|| ("n/a".into(), "n/a".into()));
        t.row(&[
            topology.shards.to_string(),
            label.clone(),
            human_us(est_round),
            cut.to_string(),
            human_bytes(halo_round),
            format!("{qps:.0}"),
            p50,
            p99,
            human_bytes(agg.halo_bytes),
        ]);
        rows_out.push(Row {
            shards: topology.shards,
            label: label.clone(),
            est_round_us: est_round,
            cut_edges: cut,
            halo_bytes_per_round: halo_round,
            qps,
        });
        let base_n = configs[0].1.shards;
        let (base_qps, base_est) = *baseline.get_or_insert((qps, est_round));
        if topology.shards > base_n {
            println!(
                "  {} shards vs {base_n}-shard baseline: {:.2}x measured, \
                 {:.2}x by the cost model",
                topology.shards,
                qps / base_qps,
                base_est / est_round.max(1e-9),
            );
        }
        serving.shutdown()?;
    }
    t.print();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let quick = args.has("quick");
    let json_path = args.options.get("json").cloned();
    banner("fleet scaling (1→8 shards, local engine, synthetic KG)");

    let sz = if quick {
        Sizes { nodes: 512, edges: 2048, queries: 200, churn: 60 }
    } else {
        Sizes { nodes: 2048, edges: 8192, queries: 1200, churn: 300 }
    };
    let homo_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4, 8] };
    let hetero_counts: &[usize] = if quick { &[2] } else { &[1, 2, 4] };

    let mut rows: Vec<Row> = Vec::new();
    let homogeneous: Vec<(String, Topology)> = homo_counts
        .iter()
        .map(|&n| (format!("{n}x series2"), Topology::homogeneous(n)))
        .collect();
    sweep("homogeneous scaling — N × Series-2 NPU", &homogeneous, &sz, &mut rows)?;

    let heterogeneous: Vec<(String, Topology)> = hetero_counts
        .iter()
        .map(|&n| (format!("{n}-way zoo"), Topology::zoo(n)))
        .collect();
    sweep(
        "heterogeneous placement — NPU2/NPU1/iGPU/CPU zoo",
        &heterogeneous,
        &sz,
        &mut rows,
    )?;

    println!(
        "\nnote: 'est round' is the planner's max_shard(compute + halo) from the\n\
         paper's cost model; 'measured q/s' is wall-clock over local-engine shards\n\
         whose work is proportional to owned nodes."
    );

    if let Some(path) = json_path {
        let mut out = String::from("{\n  \"bench\": \"fleet_scaling\",\n");
        out.push_str(&format!("  \"quick\": {quick},\n"));
        out.push_str(&format!(
            "  \"nodes\": {}, \"queries\": {},\n  \"rows\": [\n",
            sz.nodes, sz.queries
        ));
        for (i, r) in rows.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"shards\": {}, \"label\": \"{}\", \"est_round_us\": {:.3}, \
                 \"cut_edges\": {}, \"halo_bytes_per_round\": {}, \"qps\": {:.2}}}{}\n",
                r.shards,
                json_escape(&r.label),
                r.est_round_us,
                r.cut_edges,
                r.halo_bytes_per_round,
                r.qps,
                if i + 1 < rows.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        std::fs::write(&path, out)?;
        println!("wrote {path}");
    }
    Ok(())
}
