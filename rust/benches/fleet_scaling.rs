//! Fleet scaling sweep: serve the same query load from 1→8 shards and
//! report measured throughput next to the planner's estimated round cost
//! and the halo traffic each configuration pays.
//!
//! Two sweeps: homogeneous (N × Series-2 NPU — the clean scaling curve)
//! and heterogeneous (NPU2/NPU1/iGPU/CPU zoo — what the cost-model
//! placement is for). Engines are the artifact-free
//! [`grannite::fleet::LocalEngine`], whose per-query work is
//! proportional to the shard's owned nodes, so wall-clock scaling tracks
//! the partition, not PJRT.

use std::time::Instant;

use grannite::bench::banner;
use grannite::fleet::{Fleet, FleetConfig};
use grannite::graph::datasets::synthesize;
use grannite::server::Update;
use grannite::util::{human_bytes, human_us, Rng, Table};

const NODES: usize = 2048;
const EDGES: usize = 8192;
const QUERIES: usize = 1200;
const CHURN: usize = 300;

fn drive(fleet: &Fleet) -> anyhow::Result<f64> {
    // mixed load: a burst of GrAd churn, then a query storm
    let mut rng = Rng::new(11);
    for _ in 0..CHURN {
        let u = rng.usize(NODES);
        let v = (u + 1 + rng.usize(NODES - 1)) % NODES;
        fleet.update(Update::AddEdge(u.min(v), u.max(v)))?;
    }
    let t0 = Instant::now();
    let pending: Vec<_> = (0..QUERIES)
        .map(|_| fleet.query(Some(rng.usize(NODES))))
        .collect::<anyhow::Result<_>>()?;
    for rx in pending {
        rx.recv()?.map_err(anyhow::Error::msg)?;
    }
    Ok(QUERIES as f64 / t0.elapsed().as_secs_f64())
}

fn sweep(title: &str, configs: &[(String, FleetConfig)]) -> anyhow::Result<()> {
    let ds = synthesize("fleet-bench", NODES, EDGES, 6, 64, 5);
    let mut t = Table::new(
        title.to_string(),
        &[
            "shards",
            "devices",
            "est round",
            "cut edges",
            "halo/round",
            "measured q/s",
            "p50",
            "p99",
            "halo total",
        ],
    );
    let mut baseline: Option<(f64, f64)> = None; // (qps, est_round_us)
    for (label, cfg) in configs {
        let fleet = Fleet::spawn_local(&ds, NODES + 64, cfg)?;
        let est_round = fleet.plan.est_round_us;
        let cut = fleet.plan.cut_edges;
        let halo_round = fleet.plan.halo_bytes_per_round;
        let qps = drive(&fleet)?;
        let agg = fleet.metrics();
        let (p50, p99) = agg
            .latency
            .as_ref()
            .map(|l| (human_us(l.p50), human_us(l.p99)))
            .unwrap_or_else(|| ("n/a".into(), "n/a".into()));
        t.row(&[
            cfg.devices.len().to_string(),
            label.clone(),
            human_us(est_round),
            cut.to_string(),
            human_bytes(halo_round),
            format!("{qps:.0}"),
            p50,
            p99,
            human_bytes(agg.halo_bytes),
        ]);
        let base_n = configs[0].1.devices.len();
        let (base_qps, base_est) = *baseline.get_or_insert((qps, est_round));
        if cfg.devices.len() > base_n {
            println!(
                "  {} shards vs {base_n}-shard baseline: {:.2}x measured, \
                 {:.2}x by the cost model",
                cfg.devices.len(),
                qps / base_qps,
                base_est / est_round.max(1e-9),
            );
        }
        fleet.shutdown()?;
    }
    t.print();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    banner("fleet scaling (1→8 shards, LocalEngine, synthetic KG)");

    let homogeneous: Vec<(String, FleetConfig)> = [1usize, 2, 4, 8]
        .iter()
        .map(|&n| (format!("{n}× series2"), FleetConfig::homogeneous(n)))
        .collect();
    sweep("homogeneous scaling — N × Series-2 NPU", &homogeneous)?;

    let heterogeneous: Vec<(String, FleetConfig)> = [1usize, 2, 4]
        .iter()
        .map(|&n| (format!("{n}-way zoo"), FleetConfig::heterogeneous(n)))
        .collect();
    sweep("heterogeneous placement — NPU2/NPU1/iGPU/CPU zoo", &heterogeneous)?;

    println!(
        "\nnote: 'est round' is the planner's max_shard(compute + halo) from the\n\
         paper's cost model; 'measured q/s' is wall-clock over LocalEngine shards\n\
         whose work is proportional to owned nodes."
    );
    Ok(())
}
