//! Regenerates paper Fig. 20: progressive optimization speedups.
use grannite::bench::{banner, figures};
use grannite::config::HardwareConfig;
use grannite::graph::datasets;

fn main() {
    banner("Fig. 20 — progressive GraNNite speedups");
    let hw = HardwareConfig::npu_series2();
    figures::fig20(&datasets::CORA, &hw).print();
    figures::fig20(&datasets::CITESEER, &hw).print();
}
