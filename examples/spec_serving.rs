//! Spec-driven serving: load the checked-in deployment specs
//! (`examples/specs/*.toml`) and serve the same synthetic knowledge
//! graph through each — a single-leader plan, a 4-shard incremental
//! sparse fleet, and an INT8 QuantGr fleet — all through the one front
//! door, `Deployment::launch(spec, data) -> Box<dyn Serving>`.
//!
//! A new workload is a spec file, not a constructor: nothing below
//! branches on the engine or the topology.
//!
//! ```sh
//! cargo run --release --example spec_serving            # all specs
//! cargo run --release --example spec_serving -- path/to/spec.toml
//! ```

use std::time::Duration;

use grannite::serve::{DataSource, Deployment, DeploymentSpec, Serving};
use grannite::server::Update;
use grannite::util::{human_us, Rng};

const NODES: usize = 512;
const SPECS: &[&str] = &[
    "single_leader_plan.toml",
    "incremental_4shard_sparse.toml",
    "int8_fleet.toml",
];

fn specs_dir() -> std::path::PathBuf {
    // repo root or rust/ working directory — both work
    for dir in ["examples/specs", "../examples/specs"] {
        let p = std::path::PathBuf::from(dir);
        if p.is_dir() {
            return p;
        }
    }
    std::path::PathBuf::from("examples/specs")
}

fn main() -> anyhow::Result<()> {
    let ds = grannite::graph::datasets::synthesize("spec-demo", NODES, 2048, 6, 64, 42);
    let data = DataSource::Dataset(ds.clone());

    let paths: Vec<std::path::PathBuf> = match std::env::args().nth(1) {
        Some(p) => vec![p.into()],
        None => SPECS.iter().map(|f| specs_dir().join(f)).collect(),
    };

    for path in paths {
        let spec = DeploymentSpec::load(&path)?;
        println!(
            "—— {} — engine {} × {} shard(s), aggregation {}, quant {} ——",
            path.file_name().and_then(|f| f.to_str()).unwrap_or("spec"),
            spec.engine.name,
            spec.topology.shards,
            spec.aggregation.name(),
            spec.quant,
        );

        let serving = Deployment::launch(&spec, &data)?;

        // GrAd churn, then queries — a deadline-bounded wait per query
        let mut rng = Rng::new(7);
        for _ in 0..48 {
            let u = rng.usize(NODES);
            let v = (u + 1 + rng.usize(NODES - 1)) % NODES;
            serving.update(Update::AddEdge(u.min(v), u.max(v)))?;
        }
        let mut answered = 0usize;
        for n in (0..NODES).step_by(37) {
            let r = serving.query_deadline(Some(n), Duration::from_secs(30))?;
            answered += 1;
            if n == 0 {
                println!(
                    "  node 0 → class {} from shard #{} in {}",
                    r.prediction,
                    r.shard,
                    human_us(r.latency_us)
                );
            }
        }

        let snap = serving.metrics();
        let p50 = snap
            .latency
            .as_ref()
            .map(|l| human_us(l.p50))
            .unwrap_or_else(|| "n/a".into());
        println!(
            "  answered {answered} queries across {} shard(s): p50 {p50}, \
             mean batch {:.1}, mask updates {}",
            serving.num_shards(),
            snap.mean_batch,
            snap.mask_updates,
        );
        if snap.dma_bytes_dense > 0 {
            println!(
                "  mask DMA: shipped {} of {} dense-equivalent",
                grannite::util::human_bytes(snap.dma_bytes_shipped),
                grannite::util::human_bytes(snap.dma_bytes_dense),
            );
        }
        if snap.eligible_rows > 0 {
            println!(
                "  incremental: recompute ratio {:.3}, cache hit rate {:.3}",
                snap.recompute_ratio(),
                snap.cache_hit_rate(),
            );
        }
        println!("  applied version vector: {:?}", serving.sync()?);
        serving.shutdown()?;
    }
    Ok(())
}
