//! Quickstart: load the AOT artifacts, run one GCN inference through the
//! full stack (CPU-side PreG preprocessing → PJRT execution), check the
//! accuracy, and show a GrAd dynamic update — all in ~40 lines of API.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use grannite::coordinator::Coordinator;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::Path::new("artifacts");
    if !artifacts.join("manifest.toml").exists() {
        anyhow::bail!("artifacts/ missing — run `make artifacts` first");
    }

    // 1. open the coordinator: PJRT runtime + dataset + trained weights
    let mut c = Coordinator::open(artifacts, "cora")?;
    println!(
        "loaded cora twin: {} nodes / {} edges / {} classes",
        c.state.dataset.num_nodes(),
        c.state.dataset.graph.num_edges(),
        c.state.dataset.num_classes()
    );

    // 2. one StaGr inference (static graph, norm mask precomputed on CPU)
    let (logits, us) = grannite::util::timing::time_once(|| c.infer("gcn_stagr_cora"));
    let logits = logits?;
    let mask = c.state.dataset.test_mask.clone();
    println!(
        "gcn_stagr: test accuracy {:.3} in {} (first call includes XLA compile)",
        c.state.dataset.accuracy(&logits, &mask),
        grannite::util::human_us(us)
    );
    let (_, warm_us) = grannite::util::timing::time_once(|| c.infer("gcn_stagr_cora"));
    println!("warm inference: {}", grannite::util::human_us(warm_us));

    // 3. QuantGr INT8 variant — same API, quantized artifact
    let qacc = c.evaluate("gcn_quant_cora")?;
    println!("gcn_quant (INT8): test accuracy {qacc:.3}");

    // 4. GrAd: mutate the graph, re-infer through the NodePad artifact —
    //    no recompilation, just a CPU-side mask refresh
    c.state.add_edge(0, 1000)?;
    c.state.add_node()?;
    let (logits, us) = grannite::util::timing::time_once(|| c.infer("gcn_grad_cora"));
    let _ = logits?;
    println!(
        "gcn_grad after AddEdge+AddNode: re-inferred in {} (graph v{})",
        grannite::util::human_us(us),
        c.state.graph_version()
    );

    // 5. what would this cost on the Series-2 NPU? (simulator)
    let hw = grannite::config::HardwareConfig::npu_series2();
    let r = c.simulate_variant("gcn", "stagr", &hw, &Default::default())?;
    println!(
        "simulated NPU latency: {} ({:.0} inf/s)",
        grannite::util::human_us(r.total_us),
        r.throughput()
    );
    Ok(())
}
