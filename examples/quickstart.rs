//! Quickstart: run one GCN inference through the planned execution
//! engine, check plan-vs-reference equivalence, and serve GrAd dynamic
//! updates through the unified `Deployment`/`Serving` front door — all
//! in a screenful of API.
//!
//! With `make artifacts` output present this drives the full coordinator
//! stack (dataset twin + trained weights + plan-backed runtime); without
//! it, it synthesizes a Cora-sized twin and runs the same planned engine
//! offline, so the example always works.
//!
//! ```sh
//! cargo run --release --example quickstart            # offline twin
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use grannite::coordinator::Coordinator;
use grannite::engine::{PlanInstance, WorkerPool};
use grannite::ops::build::{self, GnnDims};
use grannite::ops::exec::{self, Bindings};
use grannite::ops::plan::ExecPlan;
use grannite::serve::{DataSource, Deployment, DeploymentSpec, Serving};
use grannite::server::Update;
use grannite::tensor::{Mat, Tensor};
use grannite::util::{human_bytes, human_us, timing::time_once, Rng};

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::Path::new("artifacts");
    if artifacts.join("manifest.toml").exists() {
        with_artifacts(artifacts)
    } else {
        println!("artifacts/ missing — running the offline planned-engine tour\n");
        offline()
    }
}

/// The artifact-backed tour: trained weights, accuracy, GrAd updates.
fn with_artifacts(artifacts: &std::path::Path) -> anyhow::Result<()> {
    // 1. open the coordinator: plan-backed runtime + dataset + weights
    let mut c = Coordinator::open(artifacts, "cora")?;
    println!(
        "loaded cora twin: {} nodes / {} edges / {} classes",
        c.state.dataset.num_nodes(),
        c.state.dataset.graph.num_edges(),
        c.state.dataset.num_classes()
    );

    // 2. one StaGr inference (static graph, norm mask precomputed on CPU)
    let (logits, us) = time_once(|| c.infer("gcn_stagr_cora"));
    let logits = logits?;
    let mask = c.state.dataset.test_mask.clone();
    println!(
        "gcn_stagr: test accuracy {:.3} in {} (first call compiles the plan)",
        c.state.dataset.accuracy(&logits, &mask),
        human_us(us)
    );
    let (_, warm_us) = time_once(|| c.infer("gcn_stagr_cora"));
    println!("warm planned inference: {}", human_us(warm_us));

    // 3. QuantGr INT8 variant — same API, int8 kernels inside
    let qacc = c.evaluate("gcn_quant_cora")?;
    println!("gcn_quant (INT8): test accuracy {qacc:.3}");

    // 4. GrAd: mutate the graph, re-infer through the NodePad plan —
    //    no recompilation, just a CPU-side mask refresh
    c.state.add_edge(0, 1000)?;
    c.state.add_node()?;
    let (logits, us) = time_once(|| c.infer("gcn_grad_cora"));
    let _ = logits?;
    println!(
        "gcn_grad after AddEdge+AddNode: re-inferred in {} (graph v{})",
        human_us(us),
        c.state.graph_version()
    );

    // 5. what would this cost on the Series-2 NPU? (simulator)
    let hw = grannite::config::HardwareConfig::npu_series2();
    let r = c.simulate_variant("gcn", "stagr", &hw, &Default::default())?;
    println!(
        "simulated NPU latency: {} ({:.0} inf/s)",
        human_us(r.total_us),
        r.throughput()
    );
    Ok(())
}

/// The artifact-free tour: same engine, synthesized Cora-scale twin.
fn offline() -> anyhow::Result<()> {
    // 1. a Cora-sized twin + a StaGr GCN op graph at its dimensions
    let ds = grannite::graph::datasets::synthesize("cora-twin", 2708, 5429, 7, 1433, 1);
    let dims = GnnDims::model(2708, 5429, 1433, 7);
    let g = build::gcn_stagr(dims, "stagr");

    let mut rng = Rng::new(42);
    let mut rand = |r: usize, c: usize| {
        Mat::from_fn(r, c, |_, _| (rng.f64() * 0.6 - 0.3) as f32)
    };
    let mut b: Bindings = Bindings::new();
    b.insert("norm".into(), Tensor::from_mat(&ds.graph.norm_adjacency(2708)));
    b.insert("x".into(), Tensor::from_mat(&ds.features));
    b.insert("w1".into(), Tensor::from_mat(&rand(1433, 64)));
    b.insert("b1".into(), Tensor::from_mat(&rand(1, 64)));
    b.insert("w2".into(), Tensor::from_mat(&rand(64, 7)));
    b.insert("b2".into(), Tensor::from_mat(&rand(1, 7)));

    // 2. compile once…
    let (plan, compile_us) = time_once(|| ExecPlan::compile(&g));
    let plan = Arc::new(plan?);
    println!(
        "compiled {} into {} steps in {} — {} ops fused away, arena {} \
         (vs {} unshared)",
        g.name,
        plan.num_steps(),
        human_us(compile_us),
        plan.fused_away,
        human_bytes(plan.arena_bytes()),
        human_bytes(plan.unshared_bytes()),
    );

    // 3. …run many: reference executor vs planned engine
    let (want, ref_us) = time_once(|| exec::execute_mat(&g, &b));
    let want = want?;
    let mut inst = PlanInstance::new(plan, Arc::new(WorkerPool::default_parallel()));
    inst.run(&b)?; // warm: INT8/weight caches, scratch capacity
    let (_, plan_us) = time_once(|| inst.run(&b));
    let got = inst.output_mat(0)?;
    println!(
        "reference executor {} → planned engine {} ({:.2}x), max|Δ| = {:.2e}",
        human_us(ref_us),
        human_us(plan_us),
        ref_us / plan_us,
        want.max_abs_diff(&got),
    );

    // 4. GrAd serving through the unified front door: the default
    //    DeploymentSpec is engine "plan" × 1 shard — literally the
    //    single-leader server — and the same spec with shards = 4 would
    //    launch a fleet behind the identical `Serving` trait
    let spec = DeploymentSpec { capacity: 3000, ..DeploymentSpec::default() };
    let serving = Deployment::launch(&spec, &DataSource::Dataset(ds.clone()))?;
    serving.update(Update::AddEdge(0, 1000))?;
    serving.update(Update::AddNode)?;
    let r = serving.query_wait(Some(42))?;
    println!(
        "served node 42 → class {} in {} (batch of {}, no recompile after \
         AddEdge+AddNode)",
        r.prediction,
        human_us(r.latency_us),
        r.batch_size,
    );
    // deadline-bounded queries shed through the admission path instead
    // of blocking forever
    let r = serving.query_deadline(Some(7), std::time::Duration::from_secs(30))?;
    println!("deadline-bounded query answered: node 7 → class {}", r.prediction);
    serving.shutdown()?;

    // 5. what would this cost on the Series-2 NPU? (simulator)
    let hw = grannite::config::HardwareConfig::npu_series2();
    let r = grannite::npu::simulate(&g, &hw, &Default::default());
    println!(
        "simulated NPU latency: {} ({:.0} inf/s)",
        human_us(r.total_us),
        r.throughput()
    );
    Ok(())
}
