//! Event-based vision serving (paper Fig. 1, AEGNN-style): a sliding
//! event-graph window where every frame replaces a slice of nodes and
//! rewires them spatially, then queries a GraphSAGE-max model whose
//! aggregation runs through the GrAx3 Pallas kernel (the
//! `sage_max_grax3_ev_cora` artifact is lowered at 1024-node scale with
//! the real mask-multiply + max-pool kernel inside).
//!
//! ```sh
//! make artifacts && cargo run --release --example event_vision
//! ```

use std::collections::BTreeMap;

use anyhow::Context;
use grannite::graph::stream::{EventVisionStream, GraphEvent};
use grannite::graph::Graph;
use grannite::runtime::Runtime;
use grannite::tensor::{Mat, Tensor};
use grannite::util::Rng;

const NODES: usize = 1024;
const FEATURES: usize = 16;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::Path::new("artifacts");
    if !artifacts.join("manifest.toml").exists() {
        anyhow::bail!("artifacts/ missing — run `make artifacts` first");
    }
    let rt = Runtime::open(artifacts)?;
    let artifact = "sage_max_grax3_ev_cora";
    let info = rt.artifact(artifact).context("event-vision artifact")?;
    println!("artifact {artifact}: inputs {:?}", info.inputs);

    // weights for the demo model
    let weights = grannite::runtime::io::read_gnnt(
        &artifacts.join("weights_sage_ev.gnnt"),
    )?;

    let frames: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);

    // event features: polarity/timestamp surrogates, non-negative like
    // real event-count surfaces (GrAx3's exactness precondition)
    let mut rng = Rng::new(3);
    let mut x = Mat::from_fn(NODES, FEATURES, |_, _| rng.f32());
    let mut edges: Vec<(u32, u32)> = Vec::new();
    let mut stream = EventVisionStream::new(NODES, 48, 11);

    let mut latencies = Vec::new();
    let mut processed_frames = 0;
    while processed_frames < frames {
        match stream.next().unwrap() {
            GraphEvent::AddEdge(u, v) => {
                edges.push((u as u32, v as u32));
                if edges.len() > 6 * NODES {
                    edges.drain(..NODES); // age out the oldest events
                }
                // refresh the replaced node's features (new event burst)
                for f in x.row_mut(u) {
                    *f = rng.f32();
                }
            }
            GraphEvent::Query => {
                processed_frames += 1;
                // CPU side (GraphSplit): rebuild the sampled mask for the
                // current window — dense 0/1 mask the GrAx3 kernel consumes
                let graph = Graph::new(NODES, &edges);
                let mask = graph.sampled_adjacency(grannite::SAGE_MAX_NEIGHBORS, 7, NODES);
                let mut bindings: BTreeMap<String, Tensor> = BTreeMap::new();
                bindings.insert("mask".into(), Tensor::from_mat(&mask));
                bindings.insert("x".into(), Tensor::from_mat(&x));
                for (k, v) in &weights {
                    bindings.insert(k.clone(), v.clone());
                }
                let t0 = std::time::Instant::now();
                let out = rt.execute_named(artifact, &bindings)?;
                let us = t0.elapsed().as_secs_f64() * 1e6;
                latencies.push(us);
                let logits = out.to_mat()?;
                let preds = logits.argmax_rows();
                let hist = (0..4)
                    .map(|c| preds.iter().filter(|&&p| p == c).count())
                    .collect::<Vec<_>>();
                println!(
                    "frame {processed_frames:3}: {} edges, inference {}, class histogram {:?}",
                    graph.num_edges(),
                    grannite::util::human_us(us),
                    hist
                );
            }
            _ => {}
        }
    }
    let stats = grannite::util::timing::Stats::from_samples(&latencies[1..]);
    println!("—— event-vision window: {stats} ——");
    println!(
        "fps capability (PJRT on host CPU): {:.1}",
        1e6 / stats.p50
    );
    Ok(())
}
