//! Event-based vision serving (paper Fig. 1, AEGNN-style): a sliding
//! event-graph window where every frame replaces a slice of nodes and
//! rewires them spatially, then queries a GraphSAGE-max model whose
//! GrAx3 aggregation (mask-multiply + max-pool) runs through the planned
//! execution engine — one compiled plan, arena-reused buffers, per-frame
//! mask rebinding.
//!
//! With `make artifacts` present the weights come from the trained
//! `weights_sage_ev.gnnt`; without it the demo synthesizes weights, so
//! the example (and the CI `examples` job) runs anywhere.
//!
//! ```sh
//! cargo run --release --example event_vision -- 20
//! ```

use std::collections::BTreeMap;
use std::sync::Arc;

use grannite::engine::{PlanInstance, WorkerPool};
use grannite::graph::stream::{EventVisionStream, GraphEvent};
use grannite::graph::Graph;
use grannite::ops::build::{self, GnnDims};
use grannite::ops::plan::ExecPlan;
use grannite::tensor::{Mat, Tensor};
use grannite::util::Rng;

const NODES: usize = 1024;
const FEATURES: usize = 16;
const CLASSES: usize = 4;

fn main() -> anyhow::Result<()> {
    // weights: trained (artifacts) or synthesized (offline demo) — the
    // demo measures latency/throughput, not accuracy, either way
    let artifacts = std::path::Path::new("artifacts");
    let weights_path = artifacts.join("weights_sage_ev.gnnt");
    let weights: BTreeMap<String, Tensor> = if weights_path.exists() {
        println!("using trained event-vision weights from artifacts/");
        grannite::runtime::io::read_gnnt(&weights_path)?
    } else {
        println!("artifacts/ missing — synthesizing event-vision weights");
        let mut rng = Rng::new(17);
        let mut rand = |r: usize, c: usize| {
            Tensor::from_mat(&Mat::from_fn(r, c, |_, _| (rng.f64() * 0.5 - 0.25) as f32))
        };
        let h = grannite::HIDDEN;
        let mut w = BTreeMap::new();
        w.insert("w1_self".into(), rand(FEATURES, h));
        w.insert("w1_neigh".into(), rand(FEATURES, h));
        w.insert("b1".into(), rand(1, h));
        w.insert("w2_self".into(), rand(h, CLASSES));
        w.insert("w2_neigh".into(), rand(h, CLASSES));
        w.insert("b2".into(), rand(1, CLASSES));
        w
    };

    // compile the GrAx3 SAGE-max plan once at window scale
    let dims = GnnDims::model(NODES, 6 * NODES, FEATURES, CLASSES);
    let graph_ir = build::sage_max_grax3(dims);
    let plan = Arc::new(ExecPlan::compile(&graph_ir)?);
    println!(
        "plan: {} steps, {} fused away, arena {}",
        plan.num_steps(),
        plan.fused_away,
        grannite::util::human_bytes(plan.arena_bytes()),
    );
    let mut inst = PlanInstance::new(plan, Arc::new(WorkerPool::default_parallel()));

    let frames: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);

    // event features: polarity/timestamp surrogates, non-negative like
    // real event-count surfaces (GrAx3's exactness precondition)
    let mut rng = Rng::new(3);
    let mut x = Mat::from_fn(NODES, FEATURES, |_, _| rng.f32());
    let mut edges: Vec<(u32, u32)> = Vec::new();
    let mut stream = EventVisionStream::new(NODES, 48, 11);

    let mut bindings: BTreeMap<String, Tensor> = BTreeMap::new();
    for (k, v) in &weights {
        bindings.insert(k.clone(), v.clone());
    }

    let mut latencies = Vec::new();
    let mut processed_frames = 0;
    while processed_frames < frames {
        match stream.next().unwrap() {
            GraphEvent::AddEdge(u, v) => {
                edges.push((u as u32, v as u32));
                if edges.len() > 6 * NODES {
                    edges.drain(..NODES); // age out the oldest events
                }
                // refresh the replaced node's features (new event burst)
                for f in x.row_mut(u) {
                    *f = rng.f32();
                }
            }
            GraphEvent::Query => {
                processed_frames += 1;
                // CPU side (GraphSplit): rebuild the sampled mask for the
                // current window — dense 0/1 mask the GrAx3 plan consumes
                let graph = Graph::new(NODES, &edges);
                let mask = graph.sampled_adjacency(grannite::SAGE_MAX_NEIGHBORS, 7, NODES);
                bindings.insert("mask".into(), Tensor::from_mat(&mask));
                bindings.insert("x".into(), Tensor::from_mat(&x));
                let t0 = std::time::Instant::now();
                inst.run(&bindings)?;
                let us = t0.elapsed().as_secs_f64() * 1e6;
                latencies.push(us);
                let logits = inst.output_mat(0)?;
                let preds = logits.argmax_rows();
                let hist = (0..CLASSES)
                    .map(|c| preds.iter().filter(|&&p| p == c).count())
                    .collect::<Vec<_>>();
                println!(
                    "frame {processed_frames:3}: {} edges, inference {}, class histogram {:?}",
                    graph.num_edges(),
                    grannite::util::human_us(us),
                    hist
                );
            }
            _ => {}
        }
    }
    if latencies.len() > 1 {
        // drop the first frame (cold caches) from the summary
        let stats = grannite::util::timing::Stats::from_samples(&latencies[1..]);
        println!("—— event-vision window: {stats} ——");
        println!("fps capability (planned engine): {:.1}", 1e6 / stats.p50);
    } else {
        println!("(run with ≥2 frames for latency statistics)");
    }
    Ok(())
}
