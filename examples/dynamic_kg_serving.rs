//! Dynamic knowledge-graph serving (paper Figs. 1/10): a GCN served over
//! a churning on-device knowledge graph. GrAd applies edge/node updates
//! with no recompilation; NodePad absorbs graph growth up to the
//! compiled capacity; the batcher coalesces query bursts into single
//! full-graph inferences.
//!
//! With `SHARDS > 1` the same stream is served by a fleet: GraphSplit's
//! cost model places one shard per simulated device, queries route to
//! the shard owning the node, and boundary features are charged as halo
//! traffic. With artifacts present each shard owns its own coordinator
//! (engines are built inside the shard threads); without artifacts the
//! example falls back to artifact-free `PlanEngine` shards — each serving
//! a compiled GCN `ExecPlan` — on a synthetic cora-sized twin, so it runs
//! (on the real planned-executor hot path) anywhere.
//!
//! ```sh
//! make artifacts && cargo run --release --example dynamic_kg_serving
//! cargo run --release --example dynamic_kg_serving -- 600 4   # 4 shards
//! ```

use std::time::Instant;

use grannite::coordinator::Coordinator;
use grannite::fleet::{Fleet, FleetConfig};
use grannite::graph::stream::{GraphEvent, KnowledgeGraphStream};
use grannite::server::{CoordinatorEngine, Update};

fn main() -> anyhow::Result<()> {
    let events: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(600);
    let shards: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);

    let artifacts = std::path::PathBuf::from("artifacts");
    let have_artifacts = artifacts.join("manifest.toml").exists();
    let cfg = FleetConfig::heterogeneous(shards);

    let (fleet, nodes, capacity, backend) = if have_artifacts {
        // real numerics: one PJRT coordinator per shard, built inside the
        // shard thread (PJRT handles are not Send)
        let ds = grannite::graph::datasets::Dataset::load_gnnt(&artifacts, "cora")?;
        let (nodes, capacity) = (ds.num_nodes(), 3000);
        let plan = Fleet::plan_for(&ds.graph, capacity, ds.num_features(),
                                   ds.num_classes(), &cfg)?;
        let fleet = Fleet::spawn(plan, &ds.graph, ds.num_features(), &cfg, |_spec| {
            let artifacts = artifacts.clone();
            Box::new(move || {
                // serial in-shard pool: the shards themselves are the
                // parallelism; N machine-sized pools would oversubscribe
                let pool = std::sync::Arc::new(
                    grannite::engine::WorkerPool::serial(),
                );
                let coordinator =
                    Coordinator::open_with_pool(&artifacts, "cora", pool)?;
                Ok(CoordinatorEngine {
                    coordinator,
                    artifact: "gcn_grad_cora".into(),
                })
            })
        });
        (fleet, nodes, capacity, "PJRT artifacts")
    } else {
        eprintln!("artifacts/ missing — serving the synthetic twin via planned engines");
        let ds = grannite::graph::datasets::synthesize("cora-twin", 2708, 5429, 7, 64, 1);
        let (nodes, capacity) = (2708, 3000);
        let fleet = Fleet::spawn_planned(&ds, capacity, &cfg)?;
        (fleet, nodes, capacity, "PlanEngine fallback")
    };

    println!("—— dynamic KG serving ({backend}, {shards} shard(s)) ——");
    for s in &fleet.plan.shards {
        println!(
            "  shard #{} on {:<12} owns {:4} nodes, halo in/out {}/{}",
            s.id,
            s.device.name,
            s.num_owned(),
            s.halo_in,
            s.halo_out
        );
    }

    let stream = KnowledgeGraphStream::new(nodes, capacity, 0.25, 42);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut rng = grannite::util::Rng::new(9);
    let mut active = nodes; // grows with AddNode; queries hit live nodes
    let (mut adds, mut removes, mut new_nodes) = (0usize, 0usize, 0usize);
    for ev in stream.take(events) {
        match ev {
            GraphEvent::AddEdge(u, v) => {
                adds += 1;
                fleet.update(Update::AddEdge(u, v))?;
            }
            GraphEvent::RemoveEdge(u, v) => {
                removes += 1;
                fleet.update(Update::RemoveEdge(u, v))?;
            }
            GraphEvent::AddNode => {
                new_nodes += 1;
                active += 1;
                fleet.update(Update::AddNode)?;
            }
            GraphEvent::Query => {
                pending.push(fleet.query(Some(rng.usize(active)))?);
            }
        }
    }
    let mut answered = 0;
    for rx in pending {
        if rx.recv()?.is_ok() {
            answered += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = fleet.metrics();
    println!("events: {events} (edges +{adds}/-{removes}, nodes +{new_nodes}, queries {answered})");
    if let Some(lat) = &snap.latency {
        println!("inference latency: {lat}");
    }
    if let Some(q) = &snap.queue {
        println!("queueing:          {q}");
    }
    if snap.halo_bytes > 0 {
        println!(
            "halo exchange:     {} over {} rounds",
            grannite::util::human_bytes(snap.halo_bytes),
            snap.halo_rounds
        );
    }
    println!(
        "mean batch {:.1} — {:.1} answered queries/s over {wall:.1}s wall",
        snap.mean_batch,
        answered as f64 / wall
    );
    println!(
        "version vector: sequenced {:?} applied {:?}",
        fleet.expected_versions(),
        fleet.applied_versions()
    );
    fleet.shutdown()?;
    Ok(())
}
