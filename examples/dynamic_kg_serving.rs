//! Dynamic knowledge-graph serving (paper Figs. 1/10): a GCN served over
//! a churning on-device knowledge graph. GrAd applies edge/node updates
//! with no recompilation; NodePad absorbs graph growth up to the
//! compiled capacity; the batcher coalesces query bursts into single
//! full-graph inferences.
//!
//! Everything launches through the unified serving API: one
//! `DeploymentSpec` names the engine and topology, and
//! `Deployment::launch` returns the same `Box<dyn Serving>` whether
//! that resolves to a single leader (`SHARDS = 1`) or a heterogeneous
//! fleet. With artifacts present the spec selects the `coordinator`
//! engine (real PJRT numerics, one coordinator per shard, built inside
//! the shard threads); without them it falls back to the artifact-free
//! `plan` engine on a synthetic cora-sized twin, so the example runs
//! (on the real planned-executor hot path) anywhere.
//!
//! ```sh
//! make artifacts && cargo run --release --example dynamic_kg_serving
//! cargo run --release --example dynamic_kg_serving -- 600 4   # 4 shards
//! ```

use std::time::Instant;

use grannite::graph::stream::{GraphEvent, KnowledgeGraphStream};
use grannite::serve::{
    DataSource, Deployment, DeploymentSpec, EngineRegistry, EngineSpec, Serving,
    Topology,
};
use grannite::server::Update;

fn main() -> anyhow::Result<()> {
    let events: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(600);
    let shards: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);

    let artifacts = std::path::PathBuf::from("artifacts");
    let have_artifacts = artifacts.join("manifest.toml").exists();

    let mut spec = DeploymentSpec {
        topology: Topology::zoo(shards),
        capacity: 3000,
        ..DeploymentSpec::default()
    };
    let (data, backend) = if have_artifacts {
        spec.engine = EngineSpec::named("coordinator");
        (
            DataSource::Artifacts { dir: artifacts, dataset: "cora".into() },
            "PJRT artifacts (coordinator engine)",
        )
    } else {
        eprintln!("artifacts/ missing — serving the synthetic twin via planned engines");
        spec.engine = EngineSpec::named("plan");
        let ds = grannite::graph::datasets::synthesize("cora-twin", 2708, 5429, 7, 64, 1);
        (DataSource::Dataset(ds), "PlanEngine fallback")
    };

    let ds = data.dataset()?;
    let nodes = ds.num_nodes();
    let plan = Deployment::plan(&spec, &ds)?;
    println!("—— dynamic KG serving ({backend}, {shards} shard(s)) ——");
    for s in &plan.shards {
        println!(
            "  shard #{} on {:<12} owns {:4} nodes, halo in/out {}/{}",
            s.id,
            s.device.name,
            s.num_owned(),
            s.halo_in,
            s.halo_out
        );
    }

    // ds and plan are already resolved for the placement report — launch
    // over them so nothing loads or plans twice
    let serving = Deployment::launch_at(&EngineRegistry::builtin(), &spec, &ds,
                                        data.artifacts_dir(), Some(plan.clone()))?;
    let stream = KnowledgeGraphStream::new(nodes, plan.owner.len(), 0.25, 42);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let mut rng = grannite::util::Rng::new(9);
    let mut active = nodes; // grows with AddNode; queries hit live nodes
    let (mut adds, mut removes, mut new_nodes) = (0usize, 0usize, 0usize);
    for ev in stream.take(events) {
        match ev {
            GraphEvent::AddEdge(u, v) => {
                adds += 1;
                serving.update(Update::AddEdge(u, v))?;
            }
            GraphEvent::RemoveEdge(u, v) => {
                removes += 1;
                serving.update(Update::RemoveEdge(u, v))?;
            }
            GraphEvent::AddNode => {
                new_nodes += 1;
                active += 1;
                serving.update(Update::AddNode)?;
            }
            GraphEvent::Query => {
                pending.push(serving.query(Some(rng.usize(active)))?);
            }
        }
    }
    let mut answered = 0;
    for rx in pending {
        if rx.recv()?.is_ok() {
            answered += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = serving.metrics();
    println!("events: {events} (edges +{adds}/-{removes}, nodes +{new_nodes}, queries {answered})");
    if let Some(lat) = &snap.latency {
        println!("inference latency: {lat}");
    }
    if let Some(q) = &snap.queue {
        println!("queueing:          {q}");
    }
    if snap.halo_bytes > 0 {
        println!(
            "halo exchange:     {} over {} rounds",
            grannite::util::human_bytes(snap.halo_bytes),
            snap.halo_rounds
        );
    }
    println!(
        "mean batch {:.1} — {:.1} answered queries/s over {wall:.1}s wall",
        snap.mean_batch,
        answered as f64 / wall
    );
    println!("applied version vector: {:?}", serving.sync()?);
    serving.shutdown()?;
    Ok(())
}
