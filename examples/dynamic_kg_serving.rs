//! Dynamic knowledge-graph serving (paper Figs. 1/10): a GCN served over
//! a churning on-device knowledge graph. The leader thread owns the PJRT
//! runtime; GrAd applies edge/node updates with no recompilation; NodePad
//! absorbs graph growth up to the compiled capacity; the batcher coalesces
//! query bursts into single full-graph inferences.
//!
//! ```sh
//! make artifacts && cargo run --release --example dynamic_kg_serving
//! ```

use std::time::Instant;

use grannite::coordinator::Coordinator;
use grannite::graph::stream::{GraphEvent, KnowledgeGraphStream};
use grannite::server::{CoordinatorEngine, ServerConfig, ServerHandle, Update};

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::PathBuf::from("artifacts");
    if !artifacts.join("manifest.toml").exists() {
        anyhow::bail!("artifacts/ missing — run `make artifacts` first");
    }
    let events: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(600);

    let server = ServerHandle::spawn(
        {
            let artifacts = artifacts.clone();
            move || {
                let coordinator = Coordinator::open(&artifacts, "cora")?;
                Ok(CoordinatorEngine {
                    coordinator,
                    artifact: "gcn_grad_cora".into(),
                })
            }
        },
        ServerConfig::default(),
    );

    // Cora twin as the initial knowledge graph; capacity 3000 (NodePad)
    let stream = KnowledgeGraphStream::new(2708, 3000, 0.25, 42);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    let (mut adds, mut removes, mut nodes) = (0usize, 0usize, 0usize);
    for ev in stream.take(events) {
        match ev {
            GraphEvent::AddEdge(u, v) => {
                adds += 1;
                server.update(Update::AddEdge(u, v))?;
            }
            GraphEvent::RemoveEdge(u, v) => {
                removes += 1;
                server.update(Update::RemoveEdge(u, v))?;
            }
            GraphEvent::AddNode => {
                nodes += 1;
                server.update(Update::AddNode)?;
            }
            GraphEvent::Query => pending.push(server.query(None)?),
        }
    }
    let mut answered = 0;
    for rx in pending {
        if rx.recv()?.is_ok() {
            answered += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = server.metrics.snapshot();
    println!("—— dynamic KG serving over the cora twin ——");
    println!("events: {events} (edges +{adds}/-{removes}, nodes +{nodes}, queries {answered})");
    if let Some(lat) = snap.latency {
        println!("inference latency: {lat}");
    }
    if let Some(q) = snap.queue {
        println!("queueing:          {q}");
    }
    println!(
        "mean batch {:.1} — {:.1} answered queries/s over {wall:.1}s wall",
        snap.mean_batch,
        answered as f64 / wall
    );
    server.shutdown()?;
    Ok(())
}
