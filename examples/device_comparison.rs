//! Device-comparison walkthrough: sweeps every model variant across the
//! four device models (Series-1/2 NPU, CPU, GPU) and prints a combined
//! latency/energy table — the interactive version of Figs. 21–23.
//! Works without artifacts (pure simulator).
//!
//! ```sh
//! cargo run --release --example device_comparison [cora|citeseer]
//! ```

use grannite::config::HardwareConfig;
use grannite::graph::datasets;
use grannite::npu::{simulate, SimOptions};
use grannite::ops::build::{self, GatVariant, GnnDims, QuantScales};
use grannite::util::Table;

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "cora".into());
    let spec = datasets::spec(&name)?;
    let d = GnnDims::model(spec.nodes, spec.edges, spec.features, spec.classes);

    let variants: Vec<(&str, grannite::ops::OpGraph)> = vec![
        ("gcn/stagr", build::gcn_stagr(d, "stagr")),
        ("gcn/quant", build::gcn_quant(d, QuantScales::default())),
        ("gat/effop", build::gat(d, GatVariant::EffOp)),
        ("gat/grax", build::gat(d, GatVariant::Grax)),
        ("sage_mean", build::sage_mean(d)),
        ("sage_max/grax3", build::sage_max_grax3(d)),
    ];
    let devices = [
        HardwareConfig::npu_series2(),
        HardwareConfig::npu_series1(),
        HardwareConfig::gpu(),
        HardwareConfig::cpu(),
    ];

    let mut t = Table::new(
        format!("all variants × all devices ({name})"),
        &["variant", "device", "latency", "inf/s", "energy (mJ)"],
    );
    for (vname, g) in &variants {
        for hw in &devices {
            let mut opts = SimOptions::optimized();
            opts.dense_dtype_bytes = if vname.contains("quant") { 1 } else { 2 };
            // real mask densities at this dataset's scale
            let n = spec.nodes as f64;
            let m = spec.edges as f64;
            opts.mask_density.insert("norm".into(), (2.0 * m + n) / (n * n));
            opts.mask_density.insert("mask".into(), 11.0 / n);
            opts.mask_density.insert("x".into(), 0.015);
            let r = simulate(g, hw, &opts);
            t.row(&[
                vname.to_string(),
                hw.name.clone(),
                grannite::util::human_us(r.total_us),
                format!("{:.0}", r.throughput()),
                format!("{:.3}", r.energy_mj()),
            ]);
        }
    }
    t.print();
    println!("note: CPU/GPU rows reuse the same op graphs through the\n\
              analytical device models (DESIGN.md §2); NPU rows include\n\
              GraSp+SymG+CacheG. See `grannite fig22` for the paper's\n\
              matched-precision comparison.");
    Ok(())
}
